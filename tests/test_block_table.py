"""Serving block tables: flat (NDPage) vs 2-level radix equivalence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_table as BT


def _flat(b=4, maxp=32, seed=0):
    rng = np.random.default_rng(seed)
    flat = np.full((b, maxp), -1, np.int32)
    for i in range(b):
        n = rng.integers(1, maxp + 1)
        flat[i, :n] = rng.permutation(b * maxp)[:n]
    return jnp.asarray(flat)


def test_radix_roundtrip_equals_flat():
    flat = _flat()
    radix = BT.radix_from_flat(flat, leaf_size=8)
    out = BT.translate_all(radix, BT.RADIX)
    assert (np.asarray(out) == np.asarray(flat)).all()


def test_flatten_radix_is_the_ndpage_merge():
    flat = _flat(seed=3)
    radix = BT.radix_from_flat(flat, leaf_size=4)
    merged = BT.flatten_radix(radix)
    assert (np.asarray(merged) == np.asarray(flat)).all()


def test_translate_one_agrees_with_translate_all():
    flat = _flat(seed=5)
    radix = BT.radix_from_flat(flat, leaf_size=8)
    b, maxp = flat.shape
    seq = jnp.asarray([0, 1, 2, 3])
    page = jnp.asarray([0, 3, 7, 1])
    for mode, tab in ((BT.FLAT, flat), (BT.RADIX, radix)):
        one = BT.translate_one(tab, seq, page, mode)
        allm = BT.translate_all(tab, mode)
        assert (np.asarray(one)
                == np.asarray(allm)[np.asarray(seq), np.asarray(page)]).all()


def test_table_bytes_radix_larger_when_sparse():
    """The flat table wins memory only when occupancy is high — radix keeps
    unallocated directories as -1 (the paper's space-saving argument)."""
    flat = _flat(seed=7)
    radix = BT.radix_from_flat(flat, leaf_size=8)
    assert BT.table_bytes(flat, BT.FLAT) <= BT.table_bytes(radix, BT.RADIX)


def test_occupancy_metric():
    flat = jnp.asarray(np.arange(16, dtype=np.int32).reshape(2, 8))
    lengths = jnp.asarray([8 * 4, 2 * 4])  # page_size 4
    occ = np.asarray(BT.occupancy(flat, lengths, page_size=4))
    assert occ[0] == 1.0 and occ[1] == 0.25
