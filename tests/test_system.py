"""End-to-end behaviour tests for the paper's system.

These tie the layers together: the architectural simulator reproduces the
paper's headline orderings; the serving stack's NDPage mode is semantically
transparent; training + checkpointing + data pipeline survive a restart.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_arch, smoke_variant
from repro.configs.ndp_sim import ndp_machine
from repro.models import init_params
from repro.serving import Request, ServeEngine
from repro.serving import greedy_reference
from repro.sim import simulate
from repro.workloads import generate_trace


class TestPaperClaims:
    """Fast single-workload checks of the paper's key claims; the full
    11-workload sweep lives in benchmarks/."""

    @pytest.fixture(scope="class")
    def res(self):
        return simulate(ndp_machine(2), generate_trace("rnd", 2, 4000))

    def test_mechanism_ordering(self, res):
        sp = res.speedup_vs()
        assert sp["ideal"] > sp["ndpage"] > sp["radix"] == 1.0

    def test_ndpage_reduces_walk_accesses(self, res):
        """Flattening L2/L1 + PWC at L4/L3: fewer PTE memory accesses."""
        pte_mem = res.pte_mem.mean(axis=1)
        assert pte_mem[3] < pte_mem[0]          # ndpage < radix

    def test_metadata_bypass_no_pte_l1_hits(self, res):
        """NDPage PTEs never touch the L1 (bypass -> 100% 'miss')."""
        assert res.pte_l1_miss_rate()[3] == 1.0

    def test_translation_overhead_dominates_ndp_radix(self, res):
        assert res.translation_fraction()[0] > 0.3


class TestServingTransparency:
    """NDPage's serving analogue is SOFTWARE-TRANSPARENT: flat vs radix vs
    dense caches produce identical generations."""

    @pytest.mark.slow
    def test_all_kv_modes_generate_identically(self):
        cfg = dataclasses.replace(
            smoke_variant(get_arch("granite-moe-1b-a400m")),
            dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = np.asarray([5, 9, 2, 11, 7], np.int32)
        outs = [greedy_reference(cfg, params, prompt, 6, kv_mode=m,
                                 max_len=32, page_size=4)
                for m in ("dense", "paged_flat", "paged_radix")]
        assert outs[0] == outs[1] == outs[2]


class TestEndToEnd:
    @pytest.mark.slow
    def test_train_then_serve(self, tmp_path):
        """Train a smoke model briefly, checkpoint, reload, serve it."""
        from repro.train.checkpoint import restore, save
        from repro.train.data import SyntheticLM
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_loop import init_train_state, make_train_step

        cfg = dataclasses.replace(smoke_variant(get_arch("gemma3-1b")),
                                  dtype="float32")
        state = init_train_state(cfg, jax.random.PRNGKey(1))
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=5e-3)))
        data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=4)
        for i in range(3):
            state, metrics = step(state, {k: jax.numpy.asarray(v) for k, v
                                          in data.batch_at(i).items()})
        save(str(tmp_path), 3, state.params)
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state.params)
        params, _ = restore(str(tmp_path), like)

        eng = ServeEngine(cfg, params, max_batch=2, max_len=32, page_size=4)
        eng.submit(Request(req_id=0, prompt=np.asarray([1, 2, 3], np.int32),
                           max_new_tokens=4))
        done = eng.run()
        assert len(done) == 1 and len(done[0].generated) == 4
