"""Training substrate: optimizer, microbatching, checkpoint/restart,
fault tolerance, gradient compression."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, smoke_variant
from repro.models import init_params
from repro.train.checkpoint import latest_step, restore, save
from repro.train.data import SyntheticLM, add_modality_stubs
from repro.train.fault_tolerance import FaultConfig, GuardedTrainer
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_loop import (TrainState, init_train_state, loss_fn,
                                    make_train_step)

CFG = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                          dtype="float32")
OPT = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)


def _data(cfg, b=4, s=16):
    return SyntheticLM(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b)


def _jbatch(raw):
    return {k: jnp.asarray(v) for k, v in raw.items()}


class TestOptimizer:
    def test_adamw_moves_params_and_clips(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        opt = adamw_init(params)
        grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
        new, opt, m = adamw_update(OPT, params, grads, opt)
        assert m["grad_norm"] > OPT.clip_norm
        assert not np.allclose(np.asarray(new["w"]), 1.0)
        assert int(opt["step"]) == 1

    def test_weight_decay_skips_vectors(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        opt = adamw_init(params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(
            dataclasses.replace(OPT._replace(weight_decay=0.5))
            if False else OPT._replace(weight_decay=0.5),
            params, zeros, opt)
        assert float(new["w"][0, 0]) < 1.0      # decayed
        assert float(new["b"][0]) == 1.0        # not decayed


class TestTrainLoop:
    def test_loss_decreases(self):
        state = init_train_state(CFG, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(CFG, OPT))
        data = _data(CFG)
        losses = []
        batch = _jbatch(data.batch_at(0))   # overfit one batch
        for i in range(8):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.3

    def test_microbatching_matches_full_batch(self):
        state = init_train_state(CFG, jax.random.PRNGKey(1))
        batch = _jbatch(_data(CFG).batch_at(0))
        s1, m1 = jax.jit(make_train_step(CFG, OPT, 1))(state, batch)
        s4, m4 = jax.jit(make_train_step(CFG, OPT, 4))(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-5)
        a = jax.tree.leaves(s1.params)[0]
        b = jax.tree.leaves(s4.params)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)

    def test_data_pipeline_shard_invariance(self):
        data = _data(CFG, b=8)
        full = data.batch_at(3)["tokens"]
        parts = [data.batch_at(3, rank=r, world=4)["tokens"]
                 for r in range(4)]
        assert (np.concatenate(parts) == full).all()


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        state = init_train_state(CFG, jax.random.PRNGKey(2))
        save(str(tmp_path), 7, state, extra={"data_step": 7})
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            state)
        got, extra = restore(str(tmp_path), like)
        assert extra["data_step"] == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_k(self, tmp_path):
        state = {"w": jnp.ones((2,))}
        for s in (1, 2, 3, 4):
            save(str(tmp_path), s, state, keep=2)
        dirs = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert dirs == ["step_00000003", "step_00000004"]
        assert latest_step(str(tmp_path)) == 4

    def test_restart_is_bit_exact(self, tmp_path):
        """Kill at step 3, restore, continue -> identical to uninterrupted."""
        data = _data(CFG)
        step = jax.jit(make_train_step(CFG, OPT))

        def run(n, state):
            for i in range(n):
                state, _ = step(state, _jbatch(data.batch_at(i)))
            return state

        ref = run(6, init_train_state(CFG, jax.random.PRNGKey(3)))

        st = run(3, init_train_state(CFG, jax.random.PRNGKey(3)))
        save(str(tmp_path), 3, st)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            st)
        st2, _ = restore(str(tmp_path), like)
        for i in range(3, 6):
            st2, _ = step(st2, _jbatch(data.batch_at(i)))
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(st2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_retry_then_success(self, tmp_path):
        calls = {"n": 0}

        def flaky_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient")
            return state + 1, {"loss": 0.0}

        g = GuardedTrainer(
            FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=100),
            flaky_step, state=jnp.zeros(()))
        m = g.run_step({"x": 0})
        assert m is not None and g.stats.retries == 1
        assert int(g.state) == 1

    def test_persistent_failure_restores_and_raises(self, tmp_path):
        def bad_step(state, batch):
            raise RuntimeError("broken")

        g = GuardedTrainer(
            FaultConfig(ckpt_dir=str(tmp_path), max_retries=2,
                        backoff_s=0.0),
            bad_step, state=jnp.zeros(()))
        save(str(tmp_path), 0, jnp.zeros(()))
        with pytest.raises(RuntimeError):
            g.run_step({})
        assert g.stats.retries == 2 and g.stats.restores == 1

    def test_periodic_checkpointing(self, tmp_path):
        def ok(state, batch):
            return state + 1, {}
        g = GuardedTrainer(FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=2),
                           ok, state=jnp.zeros(()))
        for _ in range(4):
            g.run_step({})
        assert latest_step(str(tmp_path)) == 4


class TestCompression:
    def test_error_feedback_converges(self):
        from repro.parallel.compression import ef_quantize, zeros_error_like
        grads = {"w": jnp.asarray([[0.301, -0.007], [2.5, 0.0011]])}
        err = zeros_error_like(grads)
        acc = jnp.zeros((2, 2))
        for _ in range(64):
            dq, err = ef_quantize(grads, err)
            acc = acc + dq["w"]
        # error feedback: long-run average == true gradient
        np.testing.assert_allclose(np.asarray(acc) / 64,
                                   np.asarray(grads["w"]), atol=0.02)

    def test_quantize_roundtrip_bounded(self):
        from repro.parallel.compression import dequantize_int8, quantize_int8
        x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                        jnp.float32)
        q, s = quantize_int8(x)
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)),
                                   np.asarray(x), atol=float(s) * 0.51)
