"""Sharding rules + an 8-device mini dry-run (subprocess: device count must
be set before jax init, and the main test process keeps 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import _rule_for, valid_spec


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


def test_valid_spec_drops_nondivisible():
    m = FakeMesh()
    assert valid_spec(P("model"), (3,), m) == P(None)
    assert valid_spec(P("model"), (4,), m) == P("model")
    assert valid_spec(P(("data", "model")), (8,), m) == P(("data", "model"))
    assert valid_spec(P(("data", "model")), (4,), m) == P("data")
    assert valid_spec(P("data", "model"), (8, 7), m) == P("data", None)


def test_param_rules():
    assert _rule_for(("stack", "mixer", "wq"), 2, True) == P("data", "model")
    assert _rule_for(("stack", "mixer", "wo"), 2, False) == P("model", None)
    assert _rule_for(("ffn", "w_up"), 3, True) == P("model", "data", None)
    assert _rule_for(("norm1", "scale"), 1, True) == P()


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro import config as C
    from repro.launch import dryrun as D

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    import dataclasses
    shape = dataclasses.replace(C.SHAPES["train_4k"], global_batch=8,
                                seq_len=256)
    D.MICROBATCH["train_4k"] = 2
    for arch in %s:
        cfg = C.smoke_variant(C.get_arch(arch))
        cfg = dataclasses.replace(cfg, name=cfg.name)
        lowered = D.lower_train(cfg, shape, mesh)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):       # pre-0.4.3x jax returns [dict]
            ca = ca[0] if ca else {}
        out[arch] = {"temp": mem.temp_size_in_bytes,
                     "flops": ca.get("flops", 0)}
    dshape = dataclasses.replace(C.SHAPES["decode_32k"], global_batch=8,
                                 seq_len=256)
    cfg = C.smoke_variant(C.get_arch("internlm2-1.8b"))
    compiled = D.lower_serve(cfg, dshape, mesh).compile()
    out["serve"] = {"temp": compiled.memory_analysis().temp_size_in_bytes}
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_mini_dryrun_8_devices():
    """Lower+compile smoke train/serve steps on a real 4x2 mesh."""
    archs = '["internlm2-1.8b", "granite-moe-1b-a400m"]'
    proc = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN % archs],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["internlm2-1.8b"]["flops"] > 0
    assert out["serve"]["temp"] > 0
