"""Fleet-scale serving: FleetScheduler/FleetEngine invariants, prefix
sharing through the refcounted pool, translation-aware admission, the
vectorized meter path, and the serving/sim facade + deprecation shims."""
import importlib
import sys
import warnings

import numpy as np
import pytest

from repro.core import block_table as BT
from repro.sim.cost_model import (TranslationCostModel, TranslationMeter,
                                  _np_row_lines_shared)
from repro.serving import FleetEngine, FleetScheduler, Request
from repro.serving.fleet import decode_trace_count
from repro.util import resilience

MODEL = TranslationCostModel.pinned()


def _engine(**kw):
    kw.setdefault("max_batch", 32)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("cost_model", MODEL)
    return FleetEngine(**kw)


def _submit_many(eng, n, *, prompt_len=6, new=5, seed=0, **req_kw):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request.build(i, rng.integers(1, 500, prompt_len),
                                 max_new_tokens=new, **req_kw))


class TestFleetBasics:
    def test_all_complete_and_pool_drains(self):
        eng = _engine()
        _submit_many(eng, 100)
        done = eng.run()
        assert len(done) == 100
        assert sorted(r.req_id for r in done) == list(range(100))
        assert all(len(r.generated) == 5 for r in done)
        s = eng.sched
        assert s.pool.free_pages == s.pool.num_pages
        assert s.num_running == 0 and not s.has_queued()
        assert s.stats["completed"] == 100
        assert s.stats["peak_running"] == 32

    def test_deterministic_and_one_decode_trace(self):
        outs = []
        t0 = decode_trace_count()
        for _ in range(2):
            eng = _engine()
            _submit_many(eng, 50)
            outs.append({r.req_id: r.generated for r in eng.run()})
        assert outs[0] == outs[1]
        # same shape -> the lru-cached jitted fn: no retrace per engine
        assert decode_trace_count() - t0 <= 1

    def test_matches_small_batch_semantics(self):
        """A fleet with batch 1 produces the same per-request stream
        lengths and scheduling stats shape as the design contract:
        every request generates exactly max_new tokens."""
        eng = _engine(max_batch=1)
        _submit_many(eng, 7, new=3)
        done = eng.run()
        assert [len(r.generated) for r in done] == [3] * 7

    def test_priority_order_admission(self):
        eng = _engine(max_batch=2)
        rng = np.random.default_rng(0)
        for i, prio in enumerate([0, 5, 1, 5]):
            eng.submit(Request.build(i, rng.integers(1, 99, 4),
                                     max_new_tokens=2, priority=prio))
        done = eng.run()
        # the two priority-5 requests finish in the first wave
        first_wave = {r.req_id for r in done[:2]}
        assert first_wave == {1, 3}

    def test_max_new_must_be_positive(self):
        eng = _engine()
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request.build(0, [1, 2], max_new_tokens=0))

    def test_too_long_request_rejected(self):
        eng = _engine(max_len=8)
        with pytest.raises(ValueError, match="exceeds"):
            eng.submit(Request.build(0, [1] * 6, max_new_tokens=8))


class TestDeadlines:
    def test_deadline_zero_drops_unadmitted(self):
        """deadline_steps=0: a request that cannot be admitted on its
        submission tick is dropped on the next sweep, never run."""
        eng = _engine(max_batch=1)
        rng = np.random.default_rng(1)
        eng.submit(Request.build(0, rng.integers(1, 99, 4),
                                 max_new_tokens=8))
        eng.submit(Request.build(1, rng.integers(1, 99, 4),
                                 max_new_tokens=2, deadline_steps=0))
        done = eng.run()
        assert [r.req_id for r in done] == [0]
        failed = list(eng.sched.failed)
        assert len(failed) == 1 and failed[0].req_id == 1
        assert failed[0].failed == "deadline"
        assert eng.sched.stats["deadline_dropped"] == 1

    def test_deadline_zero_admitted_immediately_completes(self):
        eng = _engine()
        eng.submit(Request.build(0, [1, 2, 3], max_new_tokens=2,
                                 deadline_steps=0))
        # admit on the submission tick (clock 0): the deadline sweep
        # only ever drops QUEUED requests, so once running it completes
        assert eng.sched.admit() != []
        done = eng.run()
        assert len(done) == 1 and len(done[0].generated) == 2

    def test_completed_req_id_resubmission(self):
        """Re-submitting a finished req_id is a fresh request: it runs
        again, and the meter's budgets sum across incarnations."""
        eng = _engine()
        eng.submit(Request.build(7, [1, 2, 3, 4], max_new_tokens=3))
        first = eng.run()
        assert len(first) == 1
        gen1 = list(first[0].generated)
        eng.submit(Request.build(7, [1, 2, 3, 4], max_new_tokens=3))
        second = eng.run()
        assert len(second) == 1 and list(second[0].generated) == gen1
        assert eng.sched.stats["completed"] == 2
        # one budget entry, summed over both incarnations
        budgets = eng.meter.request_budgets()
        assert set(budgets) == {7}
        np.testing.assert_allclose(budgets[7], eng.meter.total)


class TestPrefixSharing:
    def _shared_reqs(self, n, groups=2, prefix_len=8, ps=4, seed=3):
        rng = np.random.default_rng(seed)
        pfx = {g: rng.integers(1, 500, prefix_len) for g in range(groups)}
        return [Request.build(
            i, np.concatenate([pfx[i % groups],
                               rng.integers(1, 500, ps)]),
            max_new_tokens=4, prefix_id=i % groups, prefix_len=prefix_len)
            for i in range(n)]

    def test_shared_pages_are_refcounted(self):
        eng = _engine(prefix_sharing=True)
        for r in self._shared_reqs(8):
            eng.submit(r)
        s = eng.sched
        s.tick()
        s.admit()
        # 8 running, 2 groups of 4 sharers, 2 shared pages each
        # (prefix_len 8 / page_size 4): sharers map the SAME physical
        # pages and the pool counts one allocation + 3 extra refs
        assert s.num_running == 8
        assert len(s._pfx_pages) == 2
        for pid, pages in s._pfx_pages.items():
            assert len(pages) == 2
            assert all(s.pool.refcount(p) == 4 for p in pages)
        # rows of two sharers literally alias the prefix pages
        slots = np.flatnonzero(s.slot_req >= 0)
        by_group = {}
        for b in slots:
            by_group.setdefault(int(s.slot_pfx[b]), []).append(b)
        for pid, bs in by_group.items():
            rows = s.slot_pages[bs]
            assert (rows[:, :2] == s._pfx_pages[pid][None, :]).all()
            # tails are private
            assert len({int(x) for x in rows[:, 2]}) == len(bs)

    def test_shared_page_survives_sharer_eviction(self):
        """Evicting one sharer releases only ITS references: pages
        another live request maps are never freed (refcount > 0)."""
        eng = _engine(prefix_sharing=True)
        for r in self._shared_reqs(4, groups=1):
            eng.submit(r)
        s = eng.sched
        s.tick()
        s.admit()
        pages = s._pfx_pages[0].copy()
        assert all(s.pool.refcount(p) == 4 for p in pages)
        victim = s.pick_victim_slot()
        s.preempt_slot(victim, reason="test")
        assert all(s.pool.refcount(p) == 3 for p in pages)
        assert 0 in s._pfx_pages          # registry entry still alive
        # the surviving sharers' mappings are untouched
        for b in np.flatnonzero(s.slot_req >= 0):
            assert (s.slot_pages[b, :2] == pages).all()
        # finish everything (the victim re-admits after backoff)
        done = eng.run()
        assert len(done) == 4
        assert s.pool.free_pages == s.pool.num_pages
        assert not s._pfx_pages and not s._pfx_sharers

    def test_sharing_changes_radix_cycles_only(self):
        def run(sharing):
            eng = _engine(max_batch=16, max_len=32, page_size=4,
                          prefix_sharing=sharing)
            # prefix_len 16 = one FULL leaf (leaf_size 4 pages): radix
            # shared-leaf dedup only fires on fully-identical leaves
            for r in self._shared_reqs(16, groups=2, prefix_len=16):
                eng.submit(r)
            done = eng.run()
            return ({r.req_id: r.generated for r in done},
                    eng.throughput())
        gen_on, rep_on = run(True)
        gen_off, rep_off = run(False)
        assert gen_on == gen_off          # tokens are cost-independent
        cyc_on = rep_on["translation_cycles"]
        cyc_off = rep_off["translation_cycles"]
        assert cyc_on["radix"] < cyc_off["radix"]
        assert cyc_on["ndpage"] == cyc_off["ndpage"]
        assert cyc_on["ideal"] == 0.0
        tps_on, tps_off = (rep_on["tokens_per_sec"],
                           rep_off["tokens_per_sec"])
        assert tps_on["radix"] > tps_off["radix"]

    def test_np_shared_lines_match_jnp_oracle(self):
        """The meter's vectorized shared-leaf dedup equals the
        block_table pairwise oracle on random mappings with planted
        duplicate leaves."""
        import jax.numpy as jnp
        rng = np.random.default_rng(11)
        for trial in range(5):
            b, maxp, ls = 6, 16, 4
            flat = rng.integers(0, 400, (b, maxp)).astype(np.int32)
            flat[rng.random((b, maxp)) < 0.3] = -1
            flat[:, :ls] = flat[0, :ls]       # planted shared leaf
            flat[3] = -1                      # an empty row
            lf, lr = _np_row_lines_shared(flat, ls)
            want = np.asarray(
                BT.count_pte_lines_shared(jnp.asarray(flat), ls))
            np.testing.assert_array_equal(lr, want)


class TestEvictStorm:
    def _run(self, inject, n=300, seed=3):
        eng = _engine(max_batch=256, max_len=64, page_size=8)
        rng = np.random.default_rng(seed)
        for i in range(n):
            eng.submit(Request.build(i, rng.integers(1, 999, 10),
                                     max_new_tokens=12,
                                     prefix_id=i % 4, prefix_len=8))
        if inject:
            plan = resilience.FaultInjector.from_plan("evict_storm")
            with resilience.inject_faults(plan):
                done = eng.run()
        else:
            done = eng.run()
        return done, eng

    def test_bit_exact_resume_at_256_concurrent(self):
        clean, _ = self._run(False)
        storm, eng = self._run(True)
        assert eng.sched.stats["peak_running"] >= 256
        assert eng.sched.stats["preempted"] >= 3
        assert eng.sched.stats["resumed"] >= 3
        a = {r.req_id: r.generated for r in clean}
        b = {r.req_id: r.generated for r in storm}
        assert a == b
        assert eng.sched.pool.free_pages == eng.sched.pool.num_pages


class TestTranslationBudget:
    def test_budget_admits_fewer(self):
        def peak(budget):
            eng = _engine(max_batch=32, translation_budget=budget)
            _submit_many(eng, 64, new=6)
            done = eng.run()
            assert (eng.sched.stats["completed"]
                    + eng.sched.stats["shed"]) == 64
            return eng.sched.stats["peak_running"]
        free = peak(None)
        capped = peak(300.0)
        assert free == 32
        assert 0 < capped < free

    def test_budget_requires_meter(self):
        with pytest.raises(ValueError, match="meter"):
            FleetScheduler(num_pages=64, max_batch=4, page_size=4,
                           max_len=16, translation_budget=100.0)


class TestMeterSlotPath:
    def test_record_slots_equals_record_step(self):
        """The vectorized slot path prices identically to the dict
        path on the same rows (sharing off)."""
        rng = np.random.default_rng(5)
        flat = rng.integers(0, 200, (6, 8)).astype(np.int32)
        flat[rng.random((6, 8)) < 0.4] = -1
        hit = np.array([1, 0, 1, 0, 0, 1], bool)
        m1 = TranslationMeter(MODEL)
        m1.record_step(list(range(6)), hit, flat, 4)
        m2 = TranslationMeter(MODEL, max_slots=8)
        slots = np.array([7, 3, 0, 5, 1, 2])
        for s, rid in zip(slots, range(6)):
            m2.bind_slot(int(s), rid)
        m2.record_slots(slots, hit, flat, 4)
        for s in slots:
            m2.release_slot(int(s), retire=True)
        np.testing.assert_allclose(m1.total, m2.total)
        b1, b2 = m1.request_budgets(), m2.request_budgets()
        assert set(b1) == set(b2)
        for k in b1:
            np.testing.assert_allclose(b1[k], b2[k])
        assert (m1.hits, m1.misses, m1.tokens) == (m2.hits, m2.misses,
                                                   m2.tokens)

    def test_budgets_partition_total(self):
        eng = _engine()
        _submit_many(eng, 40)
        eng.run()
        acc = np.sum(list(eng.meter.request_budgets().values()), axis=0)
        np.testing.assert_allclose(acc, eng.meter.total)


class TestBoundedFailed:
    def test_fleet_failed_is_bounded(self):
        s = FleetScheduler(num_pages=64, max_batch=4, page_size=4,
                           max_len=16, failed_history=8)
        rng = np.random.default_rng(0)
        for i in range(50):
            s.submit(Request.build(i, rng.integers(1, 99, 3),
                                   max_new_tokens=2, deadline_steps=0))
        for _ in range(3):
            s.tick()
            s._deadline_sweep()
        assert s.stats["deadline_dropped"] == 50   # exact counters
        assert len(s.failed) == 8                  # bounded history

    def test_batch_scheduler_failed_is_bounded(self):
        from repro.core.kv_page_manager import KVPageManager
        from repro.serving import BatchScheduler
        kvm = KVPageManager(64, 4, 4, 16)
        s = BatchScheduler(kvm, 4, failed_history=8)
        for i in range(50):
            s.submit(Request.build(i, [1, 2, 3], max_new_tokens=2,
                                   deadline_steps=0))
        s.tick()
        s.tick()
        s._next_admissible()
        assert s.stats["deadline_dropped"] == 50
        assert len(s.failed) == 8


class TestFacadeAndShims:
    @pytest.fixture(autouse=True)
    def _unshadow_facade(self):
        """Importing a shim module (``repro.sim.sweep``) rebinds the
        package attribute ``sweep`` from the facade function to the
        shim module — Python's submodule-binding rule.  Restore the
        facade after each test so shim imports here can't leak into
        tests that use ``from repro.sim import sweep``."""
        yield
        import repro.sim as sim
        from repro.sim import _search as si
        from repro.sim import _sweep as sw
        sim.sweep, sim.search = sw.sweep, si.search

    SHIMS = {
        "repro.serving.scheduler": ("BatchScheduler", "Request"),
        "repro.serving.engine": ("ServeEngine", "greedy_reference"),
        "repro.sim.sweep": ("sweep", "run_bucketed", "apply_param"),
        "repro.sim.search": ("search", "SearchSpace"),
    }

    def test_shims_warn_once_and_reexport(self):
        for mod, names in self.SHIMS.items():
            sys.modules.pop(mod, None)
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                m = importlib.import_module(mod)
            dep = [x for x in w
                   if issubclass(x.category, DeprecationWarning)]
            assert len(dep) == 1, (mod, [str(x.message) for x in w])
            for n in names:
                assert hasattr(m, n), (mod, n)

    def test_shims_alias_the_real_objects(self):
        import repro.serving as serving
        from repro.sim import _sweep as impl_w
        sys.modules.pop("repro.serving.scheduler", None)
        sys.modules.pop("repro.sim.sweep", None)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            import repro.serving.scheduler as shim_s
            import repro.sim.sweep as shim_w
        assert shim_s.Request is serving.Request
        assert shim_s.BatchScheduler is serving.BatchScheduler
        assert shim_w.sweep is impl_w.sweep
        assert shim_w.run_bucketed is impl_w.run_bucketed

    def test_facade_exports_functions_not_modules(self):
        import repro.sim as sim
        assert callable(sim.sweep) and sim.sweep.__name__ == "sweep"
        assert callable(sim.search) and sim.search.__name__ == "search"
        assert callable(sim.run_bucketed)
        assert callable(sim.apply_param)

    def test_request_build_validates_prefix(self):
        with pytest.raises(ValueError, match="prefix_len"):
            Request.build(0, [1, 2, 3], prefix_id=1, prefix_len=9)
        r = Request.build(0, [1, 2, 3], prefix_id=1, prefix_len=2)
        assert r.prefix_id == 1 and r.submit_tick == -1
