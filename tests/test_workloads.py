"""Trace generator tests: shapes, determinism, footprint, locality knobs,
cross-process seeding stability, and the on-disk trace cache."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.ndp_sim import WORKLOADS
from repro.workloads import generate_trace, generate_traces
from repro.workloads.generators import PAGE_LINES, _pages


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_trace_shapes_and_ranges(name):
    tr = generate_trace(name, 2, 500, seed=0)
    assert tr["vpn"].shape == (2, 500)
    assert tr["off"].shape == (2, 500)
    assert (tr["vpn"] >= 0).all() and (tr["vpn"] < tr["pages"]).all()
    assert (tr["off"] >= 0).all() and (tr["off"] < PAGE_LINES).all()
    assert (tr["work"] >= 0).all()


def test_determinism():
    a = generate_trace("pr", 2, 300, seed=42)
    b = generate_trace("pr", 2, 300, seed=42)
    assert (a["vpn"] == b["vpn"]).all() and (a["off"] == b["off"]).all()


def test_cores_see_different_streams_same_dataset():
    tr = generate_trace("bc", 4, 400, seed=1)
    assert not (tr["vpn"][0] == tr["vpn"][1]).all()


def test_footprints_match_table2():
    assert _pages(8) == 8 * (1 << 18)
    assert _pages(33) == 33 * (1 << 18)


def test_stable_across_python_hash_seeds():
    """Trace seeding must not depend on Python's randomized string hash:
    the same (workload, seed) must generate identical traces in processes
    with different PYTHONHASHSEED values (regression for the old
    ``hash(workload) % 65536`` seeding)."""
    code = ("from repro.workloads import generate_trace\n"
            "import zlib\n"
            "tr = generate_trace('bfs', 2, 256, seed=9, use_cache=False)\n"
            "print(zlib.crc32(tr['vpn'].tobytes()),"
            " zlib.crc32(tr['off'].tobytes()))\n")
    digests = []
    for hash_seed in ("0", "1", "12345"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hash_seed
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr[-2000:]
        digests.append(out.stdout.strip())
    assert len(set(digests)) == 1, digests


def test_trace_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
    fresh = generate_trace("pr", 2, 300, seed=42)
    entries = [f for f in tmp_path.iterdir() if f.suffix == ".npz"]
    assert len(entries) == 1
    # every entry carries its integrity sidecar (resilience layer)
    assert entries[0].with_name(entries[0].name + ".sha256").exists()
    cached = generate_trace("pr", 2, 300, seed=42)
    for k in ("vpn", "off", "work"):
        np.testing.assert_array_equal(fresh[k], cached[k])
    assert cached["pages"] == fresh["pages"]
    # bypassing the cache regenerates the identical trace
    direct = generate_trace("pr", 2, 300, seed=42, use_cache=False)
    np.testing.assert_array_equal(direct["vpn"], cached["vpn"])


def test_trace_cache_disabled(monkeypatch):
    from repro.workloads import generators
    monkeypatch.setenv("SIM_TRACE_CACHE", "0")
    assert generators.trace_cache_dir() is None
    # and the write path really is skipped, wherever the default lives
    calls = []
    monkeypatch.setattr(generators, "_cache_store",
                        lambda path, trace: calls.append(path))
    generate_trace("pr", 2, 300, seed=42)
    assert calls == [None]


def test_generate_traces_bucket(monkeypatch, tmp_path):
    monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
    batch = generate_traces(("rnd", "bc"), 2, length=128, seed=3)
    assert len(batch) == 2
    for tr in batch:
        assert tr["vpn"].shape == (2, 128)
    single = generate_trace("bc", 2, 128, seed=3)
    np.testing.assert_array_equal(batch[1]["vpn"], single["vpn"])


def test_gups_is_irregular_and_graph_is_not():
    """GUPS: ~every access a distinct line; graph: heavy line reuse."""
    g = generate_trace("rnd", 1, 4000, seed=0)
    lines_g = g["vpn"][0].astype(np.int64) * PAGE_LINES + g["off"][0]
    h = generate_trace("bc", 1, 4000, seed=0)
    lines_h = h["vpn"][0].astype(np.int64) * PAGE_LINES + h["off"][0]
    uniq_g = len(np.unique(lines_g)) / len(lines_g)
    uniq_h = len(np.unique(lines_h)) / len(lines_h)
    assert uniq_g > 0.9
    assert uniq_h < 0.75


# ---------------------------------------------------------------------------
# parse_workload_spec: the one workload-axis parser
# ---------------------------------------------------------------------------
def test_parse_named_workload():
    from repro.workloads import parse_workload_spec
    spec = parse_workload_spec("pr")
    assert spec.kind == "named" and spec.name == "pr" and spec.opts == {}
    assert spec.canonical() == "pr"


def test_parse_unknown_named_workload_lists_knowns():
    from repro.workloads import parse_workload_spec
    with pytest.raises(KeyError, match="unknown workload 'nope'"):
        parse_workload_spec("nope")
    with pytest.raises(KeyError, match="pr"):   # message lists knowns
        parse_workload_spec("nope")


def test_parse_trace_spec_roundtrip():
    from repro.workloads import parse_workload_spec
    s = "trace:/tmp/x.csv?fmt=csv&interleave=round_robin"
    spec = parse_workload_spec(s)
    assert spec.kind == "trace" and spec.name == "/tmp/x.csv"
    assert spec.opts["fmt"] == "csv"
    assert parse_workload_spec(spec.canonical()) == spec
    moved = spec.with_path("/elsewhere/x.csv")
    assert moved.name == "/elsewhere/x.csv" and moved.opts == spec.opts


def test_parse_trace_spec_rejects_unknown_option():
    from repro.workloads import parse_workload_spec
    with pytest.raises(ValueError, match="bad option 'bogus"):
        parse_workload_spec("trace:/tmp/x.csv?bogus=1")
