"""Trace generator tests: shapes, determinism, footprint, locality knobs."""
import numpy as np
import pytest

from repro.configs.ndp_sim import WORKLOADS
from repro.workloads import generate_trace
from repro.workloads.generators import PAGE_LINES, _pages


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_trace_shapes_and_ranges(name):
    tr = generate_trace(name, 2, 500, seed=0)
    assert tr["vpn"].shape == (2, 500)
    assert tr["off"].shape == (2, 500)
    assert (tr["vpn"] >= 0).all() and (tr["vpn"] < tr["pages"]).all()
    assert (tr["off"] >= 0).all() and (tr["off"] < PAGE_LINES).all()
    assert (tr["work"] >= 0).all()


def test_determinism():
    a = generate_trace("pr", 2, 300, seed=42)
    b = generate_trace("pr", 2, 300, seed=42)
    assert (a["vpn"] == b["vpn"]).all() and (a["off"] == b["off"]).all()


def test_cores_see_different_streams_same_dataset():
    tr = generate_trace("bc", 4, 400, seed=1)
    assert not (tr["vpn"][0] == tr["vpn"][1]).all()


def test_footprints_match_table2():
    assert _pages(8) == 8 * (1 << 18)
    assert _pages(33) == 33 * (1 << 18)


def test_gups_is_irregular_and_graph_is_not():
    """GUPS: ~every access a distinct line; graph: heavy line reuse."""
    g = generate_trace("rnd", 1, 4000, seed=0)
    lines_g = g["vpn"][0].astype(np.int64) * PAGE_LINES + g["off"][0]
    h = generate_trace("bc", 1, 4000, seed=0)
    lines_h = h["vpn"][0].astype(np.int64) * PAGE_LINES + h["off"][0]
    uniq_g = len(np.unique(lines_g)) / len(lines_g)
    uniq_h = len(np.unique(lines_h)) / len(lines_h)
    assert uniq_g > 0.9
    assert uniq_h < 0.75
