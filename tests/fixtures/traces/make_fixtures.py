#!/usr/bin/env python
"""Regenerate the committed fixture traces in this directory.

The fixtures are REAL-FORMAT files (a ChampSim binary trace and a
Valgrind lackey text trace) small enough to commit (<200KB each), with
access structure matching their paired synthetic generators so
``benchmarks/trace_validate.py`` has a meaningful comparison:

* ``gups_small.champsim.xz``  — GUPS-style uniform random updates over
  a 2GB table (pairs with workload ``rnd``)
* ``graph_small.lackey.gz``   — power-law hot-vertex reads + sequential
  CSR scans over an 8GB graph (pairs with workload ``bc``)

Generation is fully seeded — rerunning this script must be a no-op for
git.  The files are hermetic CI ground truth: the ingest parsers, the
``trace:`` plumbing, and the real-vs-synthetic validation all replay
them without any network or toolchain dependency.

Usage:  python tests/fixtures/traces/make_fixtures.py
"""
from __future__ import annotations

import gzip
import lzma
import os
import sys

import numpy as np

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                     "..", "..", ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.workloads.ingest.champsim import RECORD_DTYPE  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))


def make_gups_champsim(path: str, n_records: int = 9000) -> None:
    """GUPS: ~78% of instructions carry one random 8B load into a 2GB
    table; 15% of those immediately store back (read-modify-write);
    the rest are pure compute (index arithmetic)."""
    rng = np.random.default_rng(20260731)
    rec = np.zeros(n_records, RECORD_DTYPE)
    rec["ip"] = 0x401000 + 4 * (np.arange(n_records) % 4096)
    table_base = 0x10_0000_0000
    has_mem = rng.random(n_records) < 0.78
    addr = table_base + rng.integers(0, (2 << 30) // 8, n_records) * 8
    rec["src_mem"][has_mem, 0] = addr[has_mem]
    rmw = has_mem & (rng.random(n_records) < 0.15)
    rec["dst_mem"][rmw, 0] = addr[rmw]
    with lzma.open(path, "wb", preset=9) as f:
        f.write(rec.tobytes())


def make_graph_lackey(path: str, n_accesses: int = 11000) -> None:
    """GraphBIG-style bc: 50% power-law hot-vertex property reads
    (degree-renumbered => hot ids contiguous), 35% sequential CSR edge
    scans (runs of 8 lines), 15% cold neighbour reads, over an 8GB
    graph; 2-6 'I' instruction-fetch lines between accesses."""
    rng = np.random.default_rng(988271)
    pages = 8 << 18                       # 8GB of 4KB pages
    total_lines = pages * 64
    kind = rng.choice(3, n_accesses, p=(0.5, 0.35, 0.15))
    lines = np.empty(n_accesses, np.int64)
    hot = kind == 0
    u = rng.random(n_accesses)
    lines[hot] = np.minimum((total_lines * u[hot] ** 4.2).astype(np.int64),
                            total_lines - 1)
    seq = np.flatnonzero(kind == 1)
    starts = rng.integers(0, pages, seq.size // 8 + 1) * 64
    lines[seq] = starts[np.arange(seq.size) // 8] + np.arange(seq.size) % 8
    cold = kind == 2
    lines[cold] = rng.integers(0, total_lines, int(cold.sum()))
    addr = 0x2000_0000 + lines * 64 + rng.integers(0, 8, n_accesses) * 8
    is_store = rng.random(n_accesses) < 0.12
    work = rng.integers(2, 7, n_accesses)
    out = []
    ip = 0x400000
    for i in range(n_accesses):
        for _ in range(int(work[i])):
            out.append(f"I  {ip:08x},4\n")
            ip = 0x400000 + (ip + 4 - 0x400000) % 16384
        op = "S" if is_store[i] else "L"
        out.append(f" {op} {addr[i]:010x},8\n")
    # GzipFile with mtime=0: byte-identical output run over run
    with open(path, "wb") as raw, gzip.GzipFile(
            fileobj=raw, mode="wb", compresslevel=9, mtime=0) as f:
        f.write("".join(out).encode("ascii"))


def main() -> None:
    targets = {
        "gups_small.champsim.xz": make_gups_champsim,
        "graph_small.lackey.gz": make_graph_lackey,
    }
    for name, fn in targets.items():
        path = os.path.join(HERE, name)
        fn(path)
        kb = os.path.getsize(path) / 1024
        assert kb < 200, f"{name}: {kb:.0f}KB exceeds the 200KB budget"
        print(f"wrote {name}: {kb:.1f}KB")


if __name__ == "__main__":
    main()
