"""Pipeline-parallel stage runner: matches sequential execution
(subprocess: needs >1 host device)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == 3 / 15
    assert bubble_fraction(1, 8) == 0.0


PIPE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_run

    mesh = jax.make_mesh((4,), ("stage",))
    S, M, mb, seq, d = 4, 6, 2, 8, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (S, d, d)) * 0.3

    def stage_fn(w_s, x):
        return jnp.tanh(x @ w_s)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, seq, d))
    got = pipeline_run(mesh, "stage", stage_fn, w, x)

    want = x
    for s in range(S):
        want = jnp.tanh(want @ w[s])
    err = float(jnp.abs(got - want).max())
    print("RESULT:" + str(err))
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    proc = subprocess.run(
        [sys.executable, "-c", PIPE], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    assert float(line[0][len("RESULT:"):]) < 1e-5
