"""ServeEngine behaviour: continuous batching == single-sequence oracle."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_arch, smoke_variant
from repro.core import block_table as BT
from repro.models import init_params
from repro.serving import BatchScheduler, Request, ServeEngine
from repro.serving import greedy_reference

CFG = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                          dtype="float32")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, rng.integers(3, 8))
            .astype(np.int32) for _ in range(n)]


@pytest.mark.parametrize("table_mode", [None, BT.FLAT, BT.RADIX])
def test_engine_matches_oracle(table_mode):
    eng = ServeEngine(CFG, PARAMS, max_batch=3, max_len=48, page_size=8,
                      table_mode=table_mode)
    prompts = _prompts(5)
    for i, p in enumerate(prompts):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert len(done) == 5
    for req in done:
        want = greedy_reference(CFG, PARAMS, req.prompt, 5,
                                kv_mode="paged_flat", max_len=48,
                                page_size=8)
        assert req.generated == want, (req.req_id, req.generated, want)


def test_continuous_batching_reuses_slots():
    eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=48, page_size=8)
    for i, p in enumerate(_prompts(6, seed=1)):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=3))
    done = eng.run()
    assert len(done) == 6
    assert eng.sched.stats["completed"] == 6
    assert eng.kvm.pool.free_pages == eng.kvm.pool.num_pages - 1  # scratch


def test_admission_respects_pool_capacity():
    kvm_pages = 4
    from repro.core.kv_page_manager import KVPageManager
    kvm = KVPageManager(kvm_pages, page_size=4, max_seqs=2, max_len=16)
    sched = BatchScheduler(kvm, max_batch=2)
    sched.submit(Request(req_id=0, prompt=np.zeros(12, np.int32)))
    sched.submit(Request(req_id=1, prompt=np.zeros(12, np.int32)))
    admitted = sched.admit()
    assert len(admitted) == 1            # second would exhaust the pool


def test_translation_cache_hits_on_stable_mappings():
    eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=48, page_size=8)
    for i, p in enumerate(_prompts(2, seed=2)):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=6))
    eng.run()
    assert eng.sched.tcache.hit_rate > 0.5


def test_occupancy_driven_mode_switch():
    """Fresh short sequences on big pages -> radix; dense decode -> flat."""
    from repro.core.kv_page_manager import KVPageManager
    kvm = KVPageManager(64, page_size=16, max_seqs=2, max_len=64,
                        flatten_threshold=0.5)
    sched = BatchScheduler(kvm, max_batch=2)
    sched.submit(Request(req_id=0, prompt=np.zeros(2, np.int32)))
    sched.admit()
    mode0, _, _ = sched.step_tables()
    assert mode0 == BT.RADIX             # 2/16 occupancy
    for _ in range(12):
        kvm.append_token(0)
    mode1, _, _ = sched.step_tables()
    assert mode1 == BT.FLAT              # 14/16 occupancy


# ---------------------------------------------------------------------------
# preemption-safe serving (resilience layer)
# ---------------------------------------------------------------------------
def _run_tokens(prompts, new_tokens=5, injector=None, **eng_kw):
    from repro.util import resilience
    kw = dict(max_batch=3, max_len=48, page_size=8)
    kw.update(eng_kw)
    eng = ServeEngine(CFG, PARAMS, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=new_tokens))
    if injector is not None:
        with resilience.inject_faults(injector):
            done = eng.run()
    else:
        done = eng.run()
    return eng, {r.req_id: list(r.generated) for r in done}


def test_evict_storm_is_bit_exact():
    """Three injected mid-decode evictions cost only retries: preempted
    requests re-prefill prompt + generated-so-far and every final token
    stream matches the fault-free run."""
    from repro.util import resilience
    prompts = _prompts(4, seed=5)
    _, clean = _run_tokens(prompts)
    inj = resilience.FaultInjector.from_plan("evict_storm")
    eng, faulted = _run_tokens(prompts, injector=inj)
    assert faulted == clean
    assert eng.sched.stats["preempted"] >= 3
    assert eng.sched.stats["resumed"] >= 1
    assert eng.sched.stats["shed"] == 0


def test_overload_evicts_lowest_priority_and_resumes():
    """KV pool exhaustion during decode growth sheds the lowest-
    priority runner; both requests still finish with oracle tokens."""
    prompts = [p[:4] for p in _prompts(2, seed=6)]
    eng = ServeEngine(CFG, PARAMS, max_batch=3, max_len=48, page_size=8)
    hog = eng.kvm.pool.allocate(eng.kvm.pool.free_pages - 3)
    assert hog                               # pool is genuinely tight
    eng.submit(Request(req_id=0, prompt=prompts[0], max_new_tokens=8,
                       priority=1))
    eng.submit(Request(req_id=1, prompt=prompts[1], max_new_tokens=8))
    done = eng.run(max_steps=500)
    got = {r.req_id: r.generated for r in done}
    assert eng.sched.stats["preempted"] >= 1
    assert not eng.sched.failed              # resumed, not shed
    for i in (0, 1):
        want = greedy_reference(CFG, PARAMS, prompts[i], 8,
                                kv_mode="paged_flat", max_len=48,
                                page_size=8)
        assert got[i] == want, i


def test_deadline_expired_request_is_dropped():
    prompts = [p[:4] for p in _prompts(2, seed=7)]
    eng = ServeEngine(CFG, PARAMS, max_batch=1, max_len=48, page_size=8)
    eng.submit(Request(req_id=0, prompt=prompts[0], max_new_tokens=4))
    eng.submit(Request(req_id=1, prompt=prompts[1], max_new_tokens=4,
                       deadline_steps=2))    # can't make it behind req 0
    done = eng.run(max_steps=200)
    assert [r.req_id for r in done] == [0]
    assert eng.sched.stats["deadline_dropped"] == 1
    assert [(r.req_id, r.failed) for r in eng.sched.failed] == [
        (1, "deadline")]


def test_invalidate_unknown_id_is_noop_and_recycled_ids_stay_fresh():
    """invalidate() on a never-admitted id must not bump the shared
    version floor; recycled req_ids under eviction never hit stale
    rows."""
    from repro.core.translation_cache import TranslationCache
    tc = TranslationCache(capacity=8)
    floor0 = tc.version("never-admitted")
    tc.invalidate("never-admitted")          # pure no-op
    tc.invalidate("never-admitted")
    assert tc.version("never-admitted") == floor0
    assert tc.version("any-other-id") == floor0

    # live id: insert -> invalidate advances PAST its versions
    row = np.arange(4, dtype=np.int32)
    tc.insert("req-7", None, row)
    v_live = tc.version("req-7")
    tc.invalidate("req-7")
    assert tc.version("req-7") > v_live      # recycled id starts above
    assert tc.lookup("req-7") is None        # stale row unreachable
    # double-invalidate after retirement stays a no-op
    v_after = tc.version("req-7")
    tc.invalidate("req-7")
    assert tc.version("req-7") == v_after


def test_recycled_req_id_under_eviction_reprefills_cleanly():
    """The same req_id submitted again after completion (id recycling)
    must decode exactly like a fresh id — the version floor guarantees
    no stale translation rows survive."""
    p = _prompts(1, seed=8)[0]
    eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=48, page_size=8)
    eng.submit(Request(req_id=42, prompt=p, max_new_tokens=4))
    first = eng.run()
    eng.submit(Request(req_id=42, prompt=p, max_new_tokens=4))
    second = eng.run()
    assert first[0].generated == second[0].generated
    want = greedy_reference(CFG, PARAMS, p, 4, kv_mode="paged_flat",
                            max_len=48, page_size=8)
    assert second[0].generated == want
