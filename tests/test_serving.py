"""ServeEngine behaviour: continuous batching == single-sequence oracle."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_arch, smoke_variant
from repro.core import block_table as BT
from repro.models import init_params
from repro.serving import BatchScheduler, Request, ServeEngine
from repro.serving.engine import greedy_reference

CFG = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                          dtype="float32")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, rng.integers(3, 8))
            .astype(np.int32) for _ in range(n)]


@pytest.mark.parametrize("table_mode", [None, BT.FLAT, BT.RADIX])
def test_engine_matches_oracle(table_mode):
    eng = ServeEngine(CFG, PARAMS, max_batch=3, max_len=48, page_size=8,
                      table_mode=table_mode)
    prompts = _prompts(5)
    for i, p in enumerate(prompts):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert len(done) == 5
    for req in done:
        want = greedy_reference(CFG, PARAMS, req.prompt, 5,
                                kv_mode="paged_flat", max_len=48,
                                page_size=8)
        assert req.generated == want, (req.req_id, req.generated, want)


def test_continuous_batching_reuses_slots():
    eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=48, page_size=8)
    for i, p in enumerate(_prompts(6, seed=1)):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=3))
    done = eng.run()
    assert len(done) == 6
    assert eng.sched.stats["completed"] == 6
    assert eng.kvm.pool.free_pages == eng.kvm.pool.num_pages - 1  # scratch


def test_admission_respects_pool_capacity():
    kvm_pages = 4
    from repro.core.kv_page_manager import KVPageManager
    kvm = KVPageManager(kvm_pages, page_size=4, max_seqs=2, max_len=16)
    sched = BatchScheduler(kvm, max_batch=2)
    sched.submit(Request(req_id=0, prompt=np.zeros(12, np.int32)))
    sched.submit(Request(req_id=1, prompt=np.zeros(12, np.int32)))
    admitted = sched.admit()
    assert len(admitted) == 1            # second would exhaust the pool


def test_translation_cache_hits_on_stable_mappings():
    eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=48, page_size=8)
    for i, p in enumerate(_prompts(2, seed=2)):
        eng.submit(Request(req_id=i, prompt=p, max_new_tokens=6))
    eng.run()
    assert eng.sched.tcache.hit_rate > 0.5


def test_occupancy_driven_mode_switch():
    """Fresh short sequences on big pages -> radix; dense decode -> flat."""
    from repro.core.kv_page_manager import KVPageManager
    kvm = KVPageManager(64, page_size=16, max_seqs=2, max_len=64,
                        flatten_threshold=0.5)
    sched = BatchScheduler(kvm, max_batch=2)
    sched.submit(Request(req_id=0, prompt=np.zeros(2, np.int32)))
    sched.admit()
    mode0, _, _ = sched.step_tables()
    assert mode0 == BT.RADIX             # 2/16 occupancy
    for _ in range(12):
        kvm.append_token(0)
    mode1, _, _ = sched.step_tables()
    assert mode1 == BT.FLAT              # 14/16 occupancy
