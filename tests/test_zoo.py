"""Cross-mechanism differential test harness.

EVERY mechanism in the registry — the paper five, the NDPage search
family, and the related-work zoo (victima / picorel / coda /
range_table) — runs on the same seeded smoke trace on a ctlb-enabled
multi-stack test machine, and a shared set of invariants must hold for
all of them:

  * count partition: 0 <= walks <= l1tlb_misses <= accesses, and the
    ideal mechanism never walks;
  * latencies are non-negative and total cycles are MONOTONE in
    ``memory.latency`` (a value-only change — same compiled graph);
  * a single ``simulate`` call and lanes of one ``simulate_batch``
    dispatch are BIT-EXACT per mechanism;
  * a pinned per-mechanism regression table
    (``tests/fixtures/zoo_pinned.json``) catches silent model drift.

Regenerate the pinned table after an intentional model change with:

  PYTHONPATH=src python tests/test_zoo.py --update

Registry-fragility tests ride along: ``register()`` must reject
duplicate names, walk fns whose output width disagrees with ``n_pte``,
and distinct walk-fn objects that collide on ``__qualname__`` (the
sweep-bucketing and cache-digest key) — while still allowing the
legitimate shared-function-object idiom (ndpage / ndpage_nobyp).
"""
import dataclasses
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.ndp_sim import ndp_machine
from repro.sim import mechanisms as MS
from repro.sim import simulate
from repro.sim.simulator import simulate_batch

PINNED_PATH = os.path.join(os.path.dirname(__file__), "fixtures",
                           "zoo_pinned.json")
#: the harness machine: every zoo feature armed (cache-as-TLB present,
#: multi-stack penalty nonzero) so no mechanism's model is a no-op
ZOO_TEST_CORES = 2


def zoo_test_machine(cores: int = ZOO_TEST_CORES):
    return dataclasses.replace(ndp_machine(cores), ctlb_kb=64,
                               num_stacks=4,
                               name=f"zoo-test-{cores}c")


@pytest.fixture(scope="module")
def all_mechs():
    return MS.registered_names()


@pytest.fixture(scope="module")
def zoo_res(smoke_trace, all_mechs):
    return simulate(zoo_test_machine(), smoke_trace("rnd", ZOO_TEST_CORES),
                    mechs=all_mechs, chunk=512)


class TestDifferentialInvariants:
    """The invariants every registered mechanism must satisfy on the
    shared seeded trace — a new mechanism joins the registry and is
    covered here with zero new test code."""

    def test_counts_partition(self, zoo_res, all_mechs):
        acc = zoo_res.accesses
        for i, name in enumerate(all_mechs):
            l1m = zoo_res.l1tlb_misses[i]
            walks = zoo_res.walks[i]
            assert (walks >= 0).all(), name
            assert (l1m >= 0).all(), name
            assert (walks <= l1m).all(), \
                f"{name}: more walks than L1-TLB misses"
            assert (l1m <= acc).all(), \
                f"{name}: more L1-TLB misses than lookups"
            if MS.get(name).ideal:
                assert (walks == 0).all(), f"{name}: ideal never walks"
                assert (l1m == 0).all(), f"{name}: ideal never misses"

    def test_latencies_nonnegative_and_finite(self, zoo_res, all_mechs):
        for arr in (zoo_res.cycles, zoo_res.trans_cycles,
                    zoo_res.walk_cycles):
            assert np.isfinite(arr).all()
            assert (arr >= 0).all()
        # every mechanism executes the full window: positive cycles
        assert (zoo_res.cycles > 0).all()

    def test_cycles_monotone_in_mem_latency(self, smoke_trace,
                                            all_mechs):
        mach = zoo_test_machine()
        trace = smoke_trace("rnd", ZOO_TEST_CORES)
        slow = dataclasses.replace(
            mach,
            memory=dataclasses.replace(mach.memory,
                                       latency=mach.memory.latency * 2),
            name="zoo-test-slowmem")
        base = simulate(mach, trace, mechs=all_mechs, chunk=512)
        worse = simulate(slow, trace, mechs=all_mechs, chunk=512)
        for i, name in enumerate(all_mechs):
            assert (worse.cycles[i] >= base.cycles[i] - 1e-3).all(), \
                f"{name}: cycles not monotone in memory latency"

    def test_single_vs_batch_bit_exact(self, smoke_trace, zoo_res,
                                       all_mechs):
        trace = smoke_trace("rnd", ZOO_TEST_CORES)
        b = simulate_batch(zoo_test_machine(), [trace, trace],
                           mechs=all_mechs, chunk=512)
        for lane in b:
            np.testing.assert_array_equal(zoo_res.cycles, lane.cycles)
            np.testing.assert_array_equal(zoo_res.walks, lane.walks)
            np.testing.assert_array_equal(zoo_res.l1tlb_misses,
                                          lane.l1tlb_misses)
            np.testing.assert_array_equal(zoo_res.pte_mem, lane.pte_mem)

    def test_zoo_mechs_registered(self, all_mechs):
        for name in MS.ZOO_MECHS:
            assert name in all_mechs

    def test_pinned_regression_table(self, zoo_res, all_mechs):
        assert os.path.exists(PINNED_PATH), \
            "no pinned zoo table — run: " \
            "PYTHONPATH=src python tests/test_zoo.py --update"
        with open(PINNED_PATH) as f:
            pinned = json.load(f)
        missing = [m for m in all_mechs if m not in pinned["mean_cycles"]]
        assert not missing, \
            f"mechanisms {missing} not pinned — run " \
            "PYTHONPATH=src python tests/test_zoo.py --update"
        got = zoo_res.cycles.mean(axis=1)
        for i, name in enumerate(all_mechs):
            np.testing.assert_allclose(
                got[i], pinned["mean_cycles"][name], rtol=0.05,
                err_msg=f"{name} drifted from the pinned table "
                        "(intentional model change? --update)")


class TestRegistryValidation:
    """register() fragility guards (see _validate_walk_fn)."""

    def _cleanup(self, *names):
        for n in names:
            MS._REGISTRY.pop(n, None)
        MS.tables_for.cache_clear()

    def test_duplicate_name_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            MS.register(MS.get("radix"))

    def test_overwrite_flag_allows_replacement(self):
        orig = MS.get("radix")
        try:
            got = MS.register(orig, overwrite=True)
            assert got is orig
        finally:
            MS._REGISTRY["radix"] = orig
            MS.tables_for.cache_clear()

    def test_wrong_width_walk_fn_rejected(self):
        import repro.core.page_table as PT
        try:
            with pytest.raises(ValueError, match="pad/truncate"):
                # radix4 emits 4 lines, n_pte says 2
                MS.register(MS.MechanismSpec(
                    name="tmp_wrong_width", n_pte=2,
                    pwc_levels=(True, True, False, False),
                    walk_fn=PT.radix4_walk_lines))
            assert "tmp_wrong_width" not in MS.registered_names()
        finally:
            self._cleanup("tmp_wrong_width")

    def test_qualname_collision_rejected(self):
        def make(salt):
            def walk(vpn):                    # same __qualname__ twice
                return np.asarray(vpn)[..., None] + salt
            return walk

        try:
            MS.register(MS.MechanismSpec(
                name="tmp_qn_a", n_pte=1,
                pwc_levels=(True, False, False, False),
                walk_fn=make(1)))
            with pytest.raises(ValueError, match="collides"):
                MS.register(MS.MechanismSpec(
                    name="tmp_qn_b", n_pte=1,
                    pwc_levels=(True, False, False, False),
                    walk_fn=make(2)))
            assert "tmp_qn_b" not in MS.registered_names()
        finally:
            self._cleanup("tmp_qn_a", "tmp_qn_b")

    def test_shared_walk_fn_object_allowed(self):
        # the legitimate idiom: one compiled bucket for spec variants
        # sharing one function object (ndpage / ndpage_nobyp do this)
        import repro.core.page_table as PT
        try:
            MS.register(MS.MechanismSpec(
                name="tmp_shared_fn", n_pte=4,
                pwc_levels=(True, True, True, True),
                walk_fn=PT.radix4_walk_lines))
            assert "tmp_shared_fn" in MS.registered_names()
        finally:
            self._cleanup("tmp_shared_fn")

    def test_existing_family_shares_fn_objects(self):
        # regression: the registry must keep allowing these pairs
        assert MS.get("ndpage").walk_fn is MS.get("ndpage_nobyp").walk_fn
        assert (MS.get("ndpage_search").walk_fn
                is MS.get("ndpage_pl3").walk_fn)


def _update_pinned() -> None:
    from repro.configs.ndp_sim import PRESETS
    from repro.workloads import generate_trace
    names = MS.registered_names()
    trace = generate_trace("rnd", ZOO_TEST_CORES,
                           preset=PRESETS["smoke"])
    res = simulate(zoo_test_machine(), trace, mechs=names, chunk=512)
    payload = {
        "machine": zoo_test_machine().name,
        "workload": "rnd", "preset": "smoke",
        "mean_cycles": {n: round(float(c), 1)
                        for n, c in zip(names,
                                        res.cycles.mean(axis=1))},
    }
    os.makedirs(os.path.dirname(PINNED_PATH), exist_ok=True)
    with open(PINNED_PATH, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"pinned {len(names)} mechanisms -> {PINNED_PATH}")


if __name__ == "__main__":
    if "--update" in sys.argv:
        _update_pinned()
    else:
        print(__doc__)
