"""Model zoo: per-arch smoke tests + decode/train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, list_archs, smoke_variant
from repro.models import (decode_step, forward_train, init_decode_state,
                          init_params, prefill)

KEY = jax.random.PRNGKey(0)

# families whose smoke variants still cost many seconds of XLA time each:
# the PR lane keeps one representative of every architecture class and the
# nightly full suite covers the rest (see README "Tests: tier-1 vs slow")
HEAVY = {"deepseek-v2-236b", "jamba-1.5-large-398b", "gemma3-1b",
         "internvl2-76b"}


def _arch_params(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in HEAVY else n
            for n in names]


def _batch(cfg, b=2, s=16, seed=2):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (b, s - (cfg.vision_tokens or 0)),
                              0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.full(
            (b, cfg.vision_tokens, cfg.d_model), 0.01, jnp.float32)
    if cfg.is_encdec:
        batch["audio_frames"] = jnp.full(
            (b, cfg.encoder_seq_len, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("name", _arch_params(list_archs()))
def test_smoke_forward(name):
    """REDUCED config of each assigned family: one forward step on CPU,
    correct shapes, no NaNs."""
    cfg = smoke_variant(get_arch(name))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward_train(params, cfg, batch)
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1] + (cfg.vision_tokens or 0)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", _arch_params(list_archs()))
def test_smoke_train_grad(name):
    """One backward pass: finite grads for every param leaf."""
    from repro.train.train_loop import loss_fn
    cfg = dataclasses.replace(smoke_variant(get_arch(name)), dtype="float32")
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
    assert all(jax.tree.leaves(finite))


MODES = ["dense", "paged_flat", "paged_radix"]


@pytest.mark.parametrize("name", _arch_params(
    ["internlm2-1.8b", "deepseek-v2-236b", "jamba-1.5-large-398b",
     "gemma3-1b", "granite-moe-1b-a400m", "whisper-tiny", "rwkv6-3b"]))
@pytest.mark.parametrize("mode", MODES)
def test_decode_matches_train_forward(name, mode):
    """Sequential decode (all kv modes) reproduces the training forward's
    last-position logits — validates caches, paged translation, and masks."""
    if name == "rwkv6-3b" and mode != "dense":
        pytest.skip("attention-free arch has no KV path")
    cfg = dataclasses.replace(smoke_variant(get_arch(name)), dtype="float32")
    params = init_params(cfg, KEY)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    kwargs = {}
    if cfg.is_encdec:
        af = jax.random.normal(jax.random.PRNGKey(4),
                               (b, cfg.encoder_seq_len, cfg.d_model)) * 0.02
        batch["audio_frames"] = af
        kwargs["audio_frames"] = af
    ref, _ = forward_train(params, cfg, batch)
    last, _ = prefill(params, cfg, toks, kv_mode=mode, max_len=16,
                      page_size=4, **kwargs)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_paged_flat_equals_paged_radix_any_mapping():
    """NDPage invariant: the flat table and its 2-level organization are
    semantically identical for ANY physical placement."""
    from repro.core import block_table as BT
    cfg = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                              dtype="float32")
    params = init_params(cfg, KEY)
    b, max_len, page = 2, 16, 4
    rng = np.random.default_rng(0)
    maxp = max_len // page
    perm = rng.permutation(b * maxp).astype(np.int32).reshape(b, maxp)
    flat = jnp.asarray(perm)
    radix = BT.radix_from_flat(flat, leaf_size=2)
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, 10), 0,
                              cfg.vocab_size)
    outs = []
    for mode, table in ((BT.FLAT, flat), (BT.RADIX, radix)):
        state = init_decode_state(cfg, b, max_len, kv_mode=mode,
                                  page_size=page, table=table)
        last, _ = prefill(params, cfg, toks, kv_mode=mode, state=state)
        outs.append(np.asarray(last))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)


def test_decode_state_structure():
    cfg = smoke_variant(get_arch("jamba-1.5-large-398b"))
    st = init_decode_state(cfg, batch=2, max_len=16, kv_mode="paged_flat",
                           page_size=4)
    assert st["lengths"].shape == (2,)
    assert "table" in st
    leaves = jax.tree.leaves(st["stack"])
    assert all(bool(jnp.isfinite(l).all()) for l in leaves
               if l.dtype.kind == "f")
