"""Resilience layer: integrity-checked caches, fault injection,
watchdog retries, sweep crash-resume, stage-timeout isolation.

The invariant under test everywhere: a fault may cost retries, never
answers — recovered runs are bit-exact with fault-free runs.
"""
import os
import time

import numpy as np
import pytest

from repro.util import resilience

#: chunk length unique to this file so runner-cache compile accounting
#: is exact (the cache is shared process-wide; see test_sweep.py)
CHUNK_CKPT = 288
LEN_CKPT = 600


@pytest.fixture(autouse=True)
def _clean_events():
    resilience.recovery_events(clear=True)
    yield
    resilience.recovery_events(clear=True)


# ---------------------------------------------------------------------------
# integrity-checked byte store
# ---------------------------------------------------------------------------
class TestIntegrityStore:
    def test_roundtrip_writes_sidecar(self, tmp_path):
        p = str(tmp_path / "entry.bin")
        assert resilience.write_bytes(p, b"payload")
        assert os.path.exists(p + resilience.SIDECAR_SUFFIX)
        assert resilience.read_bytes(p) == b"payload"

    def test_bitflip_quarantines(self, tmp_path):
        p = str(tmp_path / "entry.bin")
        resilience.write_bytes(p, b"payload-payload")
        raw = bytearray(open(p, "rb").read())
        raw[3] ^= 0x40                       # single bit flip
        with open(p, "wb") as f:
            f.write(raw)
        assert resilience.read_bytes(p) is None
        assert not os.path.exists(p)
        qdir = tmp_path / resilience.QUARANTINE_DIR
        assert (qdir / "entry.bin").exists()
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "quarantine" in kinds

    def test_missing_sidecar_serves_unverified(self, tmp_path):
        # legacy entries predating the sidecar format still load
        p = str(tmp_path / "old.bin")
        with open(p, "wb") as f:
            f.write(b"legacy")
        assert resilience.read_bytes(p) == b"legacy"

    def test_corrupt_npz_quarantined(self, tmp_path):
        p = str(tmp_path / "arr.npz")
        resilience.write_npz(p, {"x": np.arange(5)})
        # truncate PAST the sha check by rewriting payload+sidecar
        resilience.write_bytes(p, b"PK\x03\x04 not a real zip")
        assert resilience.read_npz(p) is None
        assert (tmp_path / resilience.QUARANTINE_DIR / "arr.npz").exists()

    def test_write_fault_degrades_to_cache_off(self, tmp_path):
        p = str(tmp_path / "w.bin")
        inj = resilience.FaultInjector(
            [resilience.Fault("cache_write", at=(0,))])
        with resilience.inject_faults(inj):
            assert resilience.write_bytes(p, b"x") is False
        assert not os.path.exists(p)
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "cache_off" in kinds


# ---------------------------------------------------------------------------
# the trace cache degrades, never crashes (the ISSUE regression)
# ---------------------------------------------------------------------------
class TestTraceCacheDegrade:
    def _gen(self, tmp_path, monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        from repro.workloads import generate_trace
        return lambda: generate_trace("rnd", 2, length=512, seed=3)

    def test_bitflipped_npz_recomputes_bit_exact(self, tmp_path,
                                                 monkeypatch):
        gen = self._gen(tmp_path, monkeypatch)
        clean = gen()
        entries = [f for f in os.listdir(tmp_path)
                   if f.endswith(".npz")]
        assert len(entries) == 1
        path = tmp_path / entries[0]
        raw = bytearray(path.read_bytes())
        raw[len(raw) // 2] ^= 0x01
        path.write_bytes(raw)
        again = gen()                       # quarantine + recompute
        for k in ("vpn", "off", "work"):
            np.testing.assert_array_equal(clean[k], again[k])
        assert (tmp_path / resilience.QUARANTINE_DIR
                / entries[0]).exists()
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "quarantine" in kinds

    def test_truncated_npz_recomputes(self, tmp_path, monkeypatch):
        gen = self._gen(tmp_path, monkeypatch)
        clean = gen()
        entries = [f for f in os.listdir(tmp_path)
                   if f.endswith(".npz")]
        path = tmp_path / entries[0]
        # truncation with a stale sidecar -> sha mismatch path
        path.write_bytes(path.read_bytes()[:64])
        again = gen()
        np.testing.assert_array_equal(clean["vpn"], again["vpn"])


# ---------------------------------------------------------------------------
# fault injection is deterministic
# ---------------------------------------------------------------------------
class TestFaultInjector:
    def test_fires_on_listed_occurrences_only(self):
        inj = resilience.FaultInjector(
            [resilience.Fault("evict", at=(0, 2))])
        assert [inj.fires("evict") for _ in range(4)] == [
            True, False, True, False]

    def test_match_scopes_the_counter(self):
        inj = resilience.FaultInjector(
            [resilience.Fault("dispatch", at=(0,), match="bucket1")])
        assert not inj.fires("dispatch", "bucket0")
        assert inj.fires("dispatch", "bucket1")

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="site"):
            resilience.Fault("frobnicate", at=(0,))

    def test_named_plans_exist(self):
        for name in ("cache_corrupt", "dispatch_hang", "evict_storm"):
            inj = resilience.FaultInjector.from_plan(name)
            assert inj.faults


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------
class TestWatchdog:
    def test_returns_result_under_deadline(self):
        assert resilience.watchdog_call(lambda: 7, 5.0) == 7

    def test_real_hang_times_out_then_retries(self):
        calls = []

        def fn():
            calls.append(1)
            if len(calls) == 1:
                time.sleep(3)
            return "ok"

        assert resilience.watchdog_call(fn, 0.2, tag="t",
                                        retries=1) == "ok"
        assert len(calls) == 2
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "watchdog_timeout" in kinds and "watchdog_retry" in kinds

    def test_exhausted_retries_propagate(self):
        def hang():
            time.sleep(3)

        with pytest.raises(resilience.DispatchTimeout):
            resilience.watchdog_call(hang, 0.2, retries=0)

    def test_inline_mode_retries_injected_timeouts(self):
        inj = resilience.FaultInjector(
            [resilience.Fault("dispatch", at=(0,))])
        calls = []

        def fn():
            calls.append(1)
            if inj.fires("dispatch"):
                raise resilience.DispatchTimeout("injected")
            return 42

        # timeout_s <= 0: inline, only injected timeouts fire
        assert resilience.watchdog_call(fn, 0, retries=1) == 42
        assert len(calls) == 2


# ---------------------------------------------------------------------------
# sweep crash-resume: finished buckets never re-dispatch
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestSweepCheckpoint:
    GRID = {"memory.latency": (100, 170), "pwc_entries": (16, 32)}

    def _sweep(self, **kw):
        from repro.sim import sweep
        return sweep(self.GRID, cores=2, trace_len=LEN_CKPT,
                     chunk=CHUNK_CKPT, **kw)

    def test_resume_skips_finished_buckets(self, tmp_path, monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        r1 = self._sweep(checkpoint=True)
        assert r1.stats["buckets"] == 2        # one per pwc_entries
        assert r1.stats["runner_compiles"] == 2  # fresh chunk -> exact
        ckpts = sorted(f for f in os.listdir(tmp_path)
                       if f.startswith("sweepckpt_")
                       and f.endswith(".npz"))
        assert len(ckpts) == 2

        # simulate a crash after bucket 0: drop bucket 1's checkpoint
        os.remove(tmp_path / ckpts[1])
        os.remove(str(tmp_path / ckpts[1]) + resilience.SIDECAR_SUFFIX)
        from repro.sim.simulator import clear_runner_cache
        clear_runner_cache()                  # cold engine, warm ckpt
        r2 = self._sweep(checkpoint=True)
        assert r2.stats["resumed_buckets"] == 1
        assert r2.stats["runner_compiles"] == 1   # ONLY the lost bucket
        resumed = [b for b in r2.stats["per_bucket"] if b.get("resumed")]
        assert len(resumed) == 1 and resumed[0]["compiles"] == 0
        for a, b in zip(r1.results.flat, r2.results.flat):
            np.testing.assert_array_equal(a.cycles, b.cycles)
            np.testing.assert_array_equal(a.walk_cycles, b.walk_cycles)
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "resume" in kinds

    def test_corrupt_checkpoint_redispatches(self, tmp_path,
                                             monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        r1 = self._sweep(checkpoint=True)
        ckpts = sorted(f for f in os.listdir(tmp_path)
                       if f.startswith("sweepckpt_")
                       and f.endswith(".npz"))
        p = tmp_path / ckpts[0]
        raw = bytearray(p.read_bytes())
        raw[10] ^= 0xFF
        p.write_bytes(raw)
        r2 = self._sweep(checkpoint=True)     # quarantine + re-dispatch
        assert r2.stats["resumed_buckets"] == 1   # the intact one
        for a, b in zip(r1.results.flat, r2.results.flat):
            np.testing.assert_array_equal(a.cycles, b.cycles)

    def test_checkpoint_off_by_default(self, tmp_path, monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        monkeypatch.delenv("SIM_SWEEP_CHECKPOINT", raising=False)
        self._sweep()
        assert not [f for f in os.listdir(tmp_path)
                    if f.startswith("sweepckpt_")]

    def test_injected_dispatch_fault_is_retried_bit_exact(
            self, tmp_path, monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        clean = self._sweep()
        inj = resilience.FaultInjector.from_plan("dispatch_hang")
        with resilience.inject_faults(inj):
            faulted = self._sweep()
        for a, b in zip(clean.results.flat, faulted.results.flat):
            np.testing.assert_array_equal(a.cycles, b.cycles)
        kinds = [k for k, _ in resilience.recovery_events()]
        assert "watchdog_retry" in kinds


# ---------------------------------------------------------------------------
# runner cache counter stays monotone across clears
# ---------------------------------------------------------------------------
def test_runner_cache_misses_monotone_across_clear():
    from repro.sim.simulator import clear_runner_cache, runner_cache_info
    before = runner_cache_info().misses
    clear_runner_cache()
    assert runner_cache_info().misses >= before


# ---------------------------------------------------------------------------
# benchmark driver: a hanging stage is TIMEOUT, not FAIL, exit nonzero
# ---------------------------------------------------------------------------
class TestStageTimeout:
    def test_hanging_stage_reports_timeout(self, tmp_path, monkeypatch,
                                           capsys):
        from benchmarks import run as bench_run
        from benchmarks import sim_figures

        def hang():
            time.sleep(5)

        monkeypatch.setattr(sim_figures, "run_all", hang)
        monkeypatch.chdir(tmp_path)           # stray outputs go here
        with pytest.raises(SystemExit) as exc:
            bench_run.main(["--sim-only", "--stage-timeout", "0.3"])
        assert exc.value.code != 0
        out = capsys.readouterr().out
        assert "TIMEOUT" in out and "figures" in out
        assert "FAIL    figures" not in out

    def test_failing_stage_still_fail_not_timeout(self, tmp_path,
                                                  monkeypatch, capsys):
        from benchmarks import run as bench_run
        from benchmarks import sim_figures

        def boom():
            raise RuntimeError("broken stage")

        monkeypatch.setattr(sim_figures, "run_all", boom)
        monkeypatch.chdir(tmp_path)
        with pytest.raises(SystemExit) as exc:
            bench_run.main(["--sim-only", "--stage-timeout", "30"])
        assert exc.value.code != 0
        out = capsys.readouterr().out
        assert "FAIL" in out and "TIMEOUT" not in out
