"""Docs cannot drift from code: the generated mechanism table matches a
fresh render of the registry, and every relative markdown link in
README/ROADMAP/docs resolves."""
import importlib.util
import os

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _load(script):
    path = os.path.join(ROOT, "scripts", script)
    spec = importlib.util.spec_from_file_location(script[:-3], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestMechDocs:
    def test_regenerating_produces_no_diff(self):
        gen = _load("gen_mech_docs.py")
        with open(gen.DOC_PATH) as f:
            committed = f.read()
        assert committed == gen.render(), (
            "docs/mechanisms.md is stale — regenerate with "
            "`PYTHONPATH=src python scripts/gen_mech_docs.py`")

    def test_check_mode_passes(self):
        gen = _load("gen_mech_docs.py")
        assert gen.main(["--check"]) == 0

    def test_every_registered_mechanism_documented(self):
        from repro.sim import mechanisms as MS
        gen = _load("gen_mech_docs.py")
        text = gen.render()
        for name in MS.registered_names():
            assert f"| `{name}` " in text, name


class TestLinks:
    def test_no_broken_relative_links(self):
        chk = _load("check_links.py")
        files = chk.iter_md([os.path.join(ROOT, "README.md"),
                             os.path.join(ROOT, "ROADMAP.md"),
                             os.path.join(ROOT, "docs")])
        assert len(files) >= 4            # README + ROADMAP + 3 docs
        bad = {f: chk.broken_links(f) for f in files}
        bad = {f: b for f, b in bad.items() if b}
        assert not bad, f"broken markdown links: {bad}"
