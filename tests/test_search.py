"""Design-space search: seeded determinism, Pareto/dominance laws,
the 1-candidate == sweep() bit-exactness bridge, the compile-count
bound over a full generation, and the BENCH/baseline plumbing.

Chunk lengths 416/448 are unique to this file so runner-cache compile
accounting is exact (the cache is keyed on (shape, walk fns, chunk,
batched) and shared process-wide).
"""
import itertools
import json
import os
import sys

import numpy as np
import pytest

from repro.sim._search import (OBJECTIVES, SearchSpace, build_machine,
                              dominates, evaluate_genomes, mech_for,
                              merge_search_section, paper_genome,
                              pareto_indices, search, sram_kb)
from repro.sim import sweep

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import sim_search  # noqa: E402

CHUNK = 416
CHUNK_FRESH = 448      # compile-bound test only: fresh runner keys
LEN = 416


def _space(**over):
    base = dict(
        name="tiny",
        knobs=(("pwc_entries", (16, 32)),
               ("flatten", ("pl2", "pl3")),
               ("l1_bypass", (True, False))),
        cores=2, workloads=("rnd", "xs"),
        n_random=5, population=8, generations=2, offspring=4,
        trace_len=LEN, chunk=CHUNK, preset="smoke", seed=11)
    base.update(over)
    return SearchSpace(**base)


@pytest.fixture(scope="module")
def res():
    """One search over the tiny space, shared by the read-only tests."""
    return search(_space(), use_cache=False)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_same_seed_bit_identical_frontier(res):
    """The same seed over the same space must reproduce the frontier
    bit-for-bit: same genomes, same order, same objective floats."""
    again = search(_space(), use_cache=False)
    assert [dict(c.genome) for c in again.frontier] == \
           [dict(c.genome) for c in res.frontier]
    assert [c.objectives for c in again.frontier] == \
           [c.objectives for c in res.frontier]
    assert [dict(c.genome) for c in again.candidates] == \
           [dict(c.genome) for c in res.candidates]
    assert again.verdict["dominates_paper"] == \
        res.verdict["dominates_paper"]
    assert again.provenance["evaluated"] == res.provenance["evaluated"]


def test_provenance_and_verdict(res):
    p = res.provenance
    assert p["seed"] == 11
    assert p["evaluated"] == len(res.candidates) >= 6
    # no-recompile invariant: compiles bounded by the distinct
    # (machine-shape x walk-fn) buckets, never the candidate count
    assert p["runner_compiles"] <= p["distinct_buckets"]
    assert isinstance(res.verdict["dominates_paper"], bool)
    assert res.paper.origin == "paper"
    assert dict(res.paper.genome) == dict(
        zip(("pwc_entries", "flatten", "l1_bypass"), (32, "pl2", True)))


# ---------------------------------------------------------------------------
# 1-candidate search == direct sweep() point
# ---------------------------------------------------------------------------
def test_single_candidate_bit_exact_vs_sweep():
    """A degenerate 1-genome search (the paper point, whose geometry IS
    the ndp default machine) must reproduce a direct sweep() over the
    same workloads counter-for-counter."""
    space = _space(knobs=(("pwc_entries", (32,)),),
                   n_random=0, generations=0, offspring=0)
    r = search(space, use_cache=False)
    assert len(r.candidates) == 1
    cand = r.candidates[0]
    assert cand.mech == "ndpage"

    sw = sweep({"workload": space.workloads}, cores=space.cores,
               mechs=("radix", "ndpage"), trace_len=LEN, chunk=CHUNK)
    worst = -np.inf
    for wl in space.workloads:
        pt = sw.point(workload=wl)
        assert pt.speedup_vs("radix")["ndpage"] == \
            cand.per_workload[wl], wl
        worst = max(worst, pt.scalar("avg_ptw_latency", "ndpage"))
    assert cand.objectives["worst_ptw"] == worst
    assert cand.objectives["mean_speedup"] == float(
        np.mean(list(cand.per_workload.values())))
    assert cand.objectives["sram_kb"] == sram_kb(
        space, paper_genome(space))


# ---------------------------------------------------------------------------
# dominance / frontier laws (seeded random objective vectors)
# ---------------------------------------------------------------------------
def test_frontier_contains_no_dominated_points():
    rng = np.random.default_rng(0)
    names = [n for n, _ in OBJECTIVES]
    for _ in range(25):
        vecs = [dict(zip(names, row))
                for row in rng.random((rng.integers(1, 20), 3))]
        front = set(pareto_indices(vecs))
        assert front, "frontier can never be empty"
        for i, v in enumerate(vecs):
            dominated = any(dominates(w, v)
                            for j, w in enumerate(vecs) if j != i)
            assert (i in front) == (not dominated)
        # dominance is irreflexive and asymmetric
        for v in vecs:
            assert not dominates(v, v)
        for a in vecs:
            for b in vecs:
                assert not (dominates(a, b) and dominates(b, a))


def test_search_frontier_is_nondominated(res):
    vecs = [c.objectives for c in res.frontier]
    assert pareto_indices(vecs) == list(range(len(vecs)))
    # and every non-frontier candidate is dominated by some frontier pt
    for c in res.candidates:
        if c.objectives in vecs:
            continue
        assert any(dominates(f.objectives, c.objectives)
                   for f in res.frontier), c.genome


# ---------------------------------------------------------------------------
# compile bound across a full >= 24-candidate generation
# ---------------------------------------------------------------------------
def test_generation_compile_count_bounded_by_buckets():
    """24 candidates spanning 3 machine shapes x 8 mechanism structures
    dispatch as exactly (shape x walk-fn-tuple) buckets: 6 runner
    compiles, not 24."""
    space = _space(knobs=(("pwc_entries", (8, 16, 32)),
                          ("flatten", ("pl2", "pl3")),
                          ("l1_bypass", (True, False)),
                          ("huge", (False, True))),
                   workloads=("rnd",), chunk=CHUNK_FRESH)
    genomes = [tuple(g) for g in itertools.product(
        (8, 16, 32), ("pl2", "pl3"), (True, False), (False, True))]
    assert len(genomes) == 24
    evals, stats = evaluate_genomes(space, genomes)
    assert len(evals) == 24
    assert stats["points"] == 24                    # one workload each
    # bypass/huge are value-only lane data; only (pwc shape x flatten
    # walk-fn) forces a bucket -> 3 shapes x 2 walk fns
    assert stats["buckets"] == 6
    assert stats["distinct_shapes"] == 3
    assert stats["runner_compiles"] == 6
    # every structural combo really got its own mechanism variant
    assert len({mech_for(space, g) for g in genomes}) == 8


def test_geometry_knobs_reach_the_machine():
    space = _space(knobs=(("pwc_entries", (16, 32)),
                          ("l1_dtlb", ((64, 4), (128, 8))),
                          ("l2_tlb.entries", (1536, 3072))))
    g = (16, (128, 8), 3072)
    mach = build_machine(space, g)
    assert mach.pwc_entries == 16
    assert (mach.l1_dtlb.entries, mach.l1_dtlb.ways) == (128, 8)
    assert mach.l2_tlb.entries == 3072
    assert sram_kb(space, g) == (16 * 4 * 8 + 128 * 8 + 3072 * 8) / 1024


# ---------------------------------------------------------------------------
# BENCH_sim.json merge + frontier baseline gate
# ---------------------------------------------------------------------------
def test_merge_never_clobbers_other_sections(tmp_path):
    path = str(tmp_path / "BENCH_sim.json")
    with open(path, "w") as f:
        json.dump({"figures_wall_s": 1.0, "sweeps": {"pwc_size": {}},
                   "serving": {"x": 1}}, f)
    merge_search_section({"frontier": []}, path)
    with open(path) as f:
        data = json.load(f)
    assert data["figures_wall_s"] == 1.0
    assert data["sweeps"] == {"pwc_size": {}}
    assert data["serving"] == {"x": 1}
    assert data["search"] == {"frontier": []}


def test_frontier_baseline_roundtrip(res, tmp_path):
    """Pinning the discovered frontier and re-checking it passes; a
    baseline pinning a dominated genome fails the gate."""
    path = str(tmp_path / "frontier_baseline.json")
    sim_search.update_baseline(res, path)
    ok, note = sim_search.check_frontier_baseline(res, path)
    assert ok, note

    dominated = [c for c in res.candidates
                 if any(dominates(f.objectives, c.objectives)
                        for f in res.frontier)]
    if not dominated:
        pytest.skip("tiny space produced no dominated candidate")
    with open(path) as f:
        base = json.load(f)
    base["points"] = [dominated[0].to_json_dict()]
    with open(path, "w") as f:
        json.dump(base, f)
    ok, note = sim_search.check_frontier_baseline(res, path)
    assert not ok and "dominated" in note

    # and a missing baseline is a skip, not a failure
    ok, note = sim_search.check_frontier_baseline(
        res, str(tmp_path / "absent.json"))
    assert ok and "no baseline" in note


def test_eval_cache_reuse(tmp_path, monkeypatch):
    """A warm on-disk eval cache must reproduce the frontier without a
    single new simulation lane."""
    monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path / "cache"))
    space = _space(n_random=2, generations=1, offspring=2)
    cold = search(space, use_cache=True)
    warm = search(space, use_cache=True)
    assert warm.provenance["lanes_dispatched"] == 0
    assert warm.provenance["eval_cache_hits"] > 0
    assert [c.objectives for c in warm.frontier] == \
           [c.objectives for c in cold.frontier]
