"""Real-trace ingestion tests (ISSUE-4).

Edge cases: truncated/corrupt records, gz vs xz vs plain parity, empty
traces, page-size override changing the vpn split, interleaving modes,
cache hits bit-exact vs cold parses — plus the acceptance criterion:
the committed fixture traces replay through ``simulate_batch`` and
``sweep()`` bit-exactly cached vs uncached.
"""
import dataclasses
import gzip
import lzma
import os

import numpy as np
import pytest

from repro.workloads import generate_trace
from repro.workloads.ingest import (TraceFormatError, detect_format,
                                    ingest_trace, parse_trace_spec)
from repro.workloads.ingest.champsim import RECORD_DTYPE

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "traces")
GUPS_FIX = os.path.join(FIXDIR, "gups_small.champsim.xz")
GRAPH_FIX = os.path.join(FIXDIR, "graph_small.lackey.gz")


# ---------------------------------------------------------------------------
# synthetic trace-file builders
# ---------------------------------------------------------------------------
def champsim_records(n=600, seed=0, mem_prob=0.8):
    """A deterministic ChampSim record array: ~mem_prob of instructions
    carry one source memory access over a small sequential+random mix."""
    rng = np.random.default_rng(seed)
    rec = np.zeros(n, RECORD_DTYPE)
    rec["ip"] = 0x400000 + 4 * np.arange(n)
    has = rng.random(n) < mem_prob
    addr = 0x7f0000000 + rng.integers(0, 1 << 20, n) * 64
    rec["src_mem"][has, 0] = addr[has]
    return rec


def write_champsim(path, rec):
    raw = rec.tobytes()
    if str(path).endswith(".xz"):
        with lzma.open(path, "wb") as f:
            f.write(raw)
    elif str(path).endswith(".gz"):
        with gzip.open(path, "wb") as f:
            f.write(raw)
    else:
        with open(path, "wb") as f:
            f.write(raw)
    return str(path)


# ---------------------------------------------------------------------------
# parsing + robustness
# ---------------------------------------------------------------------------
class TestParsers:
    def test_gz_xz_plain_parity(self, tmp_path):
        """The same records must ingest identically from .xz, .gz and
        uncompressed files (the sha256 cache key differs, the parse
        must not)."""
        rec = champsim_records()
        traces = []
        for suffix in ("a.champsim", "b.champsim.gz", "c.champsim.xz"):
            p = write_champsim(tmp_path / suffix, rec)
            traces.append(ingest_trace(p, 2, length=100, use_cache=False))
        for t in traces[1:]:
            for k in ("vpn", "off", "work"):
                np.testing.assert_array_equal(traces[0][k], t[k])
            assert t["pages"] == traces[0]["pages"]

    def test_truncated_champsim_record_raises(self, tmp_path):
        rec = champsim_records(100)
        p = tmp_path / "trunc.champsim"
        with open(p, "wb") as f:
            f.write(rec.tobytes()[:-13])        # tear the last record
        with pytest.raises(TraceFormatError, match="truncated"):
            ingest_trace(str(p), 2, use_cache=False)

    def test_empty_and_memoryless_traces_raise(self, tmp_path):
        empty = tmp_path / "empty.champsim"
        empty.write_bytes(b"")
        with pytest.raises(TraceFormatError, match="no memory accesses"):
            ingest_trace(str(empty), 2, use_cache=False)
        # records parse fine but none carries a memory operand
        rec = champsim_records(50, mem_prob=0.0)
        p = write_champsim(tmp_path / "nomem.champsim", rec)
        with pytest.raises(TraceFormatError, match="no memory accesses"):
            ingest_trace(p, 2, use_cache=False)

    def test_corrupt_lackey_line_raises(self, tmp_path):
        p = tmp_path / "bad.lackey"
        p.write_text("I  04000000,3\n L 04e2b848,8\nXYZZY 123\n")
        with pytest.raises(TraceFormatError, match="bad.lackey:3"):
            ingest_trace(str(p), 1, use_cache=False)
        p.write_text(" L nothex,8\n")
        with pytest.raises(TraceFormatError, match="bad lackey address"):
            ingest_trace(str(p), 1, use_cache=False)

    def test_lackey_work_counts_instruction_fetches(self, tmp_path):
        p = tmp_path / "w.lackey"
        p.write_text("I  04000000,3\nI  04000004,3\n L 00001000,8\n"
                     " S 00002000,8\nI  04000008,3\n M 00003000,4\n")
        tr = ingest_trace(str(p), 1, use_cache=False)
        assert tr["work"].tolist() == [[2, 0, 1]]

    def test_csv_header_and_positional(self, tmp_path):
        h = tmp_path / "h.csv"
        h.write_text("tid,addr,work\n0,0x1000,3\n1,0x2000,2\n"
                     "0,0x1040,1\n1,0x2040,4\n")
        tr = ingest_trace(str(h), 2, interleave="thread", use_cache=False)
        assert tr["vpn"].shape == (2, 2)
        assert tr["work"].tolist() == [[3, 1], [2, 4]]
        pos = tmp_path / "p.csv"
        pos.write_text("0x1000\n0x2000\n0x1040\n0x2040\n")
        tr2 = ingest_trace(str(pos), 2, use_cache=False)   # round-robin
        assert tr2["vpn"].shape == (2, 2)

    def test_csv_bad_rows_raise(self, tmp_path):
        p = tmp_path / "bad.csv"
        p.write_text("addr,work\n0x1000,1\n0x2000\n")
        with pytest.raises(TraceFormatError, match="expected 2 fields"):
            ingest_trace(str(p), 1, use_cache=False)
        p.write_text("addr,nope\n0x1000,1\n")
        with pytest.raises(TraceFormatError, match="unknown column"):
            ingest_trace(str(p), 1, use_cache=False)

    def test_detect_format_and_spec_parsing(self):
        assert detect_format("x.champsim.xz") == "champsim"
        assert detect_format("runs/app.trace.gz") == "champsim"
        assert detect_format("mem.lackey.gz") == "lackey"
        assert detect_format("t.csv") == "csv"
        with pytest.raises(TraceFormatError, match="cannot infer"):
            detect_format("mystery.bin")
        path, opts = parse_trace_spec(
            "trace:/tmp/a.csv?interleave=thread&page_bytes=8192")
        assert path == "/tmp/a.csv"
        assert opts == {"interleave": "thread", "page_bytes": 8192}
        with pytest.raises(ValueError, match="bad option"):
            parse_trace_spec("trace:/tmp/a.csv?nope=1")


# ---------------------------------------------------------------------------
# pipeline semantics
# ---------------------------------------------------------------------------
class TestPipeline:
    def test_page_size_override_changes_vpn_split(self, tmp_path):
        """A sequential 128KB scan: doubling the page size must halve
        the distinct vpns and widen the line-offset range."""
        p = tmp_path / "seq.csv"
        p.write_text("\n".join(f"0x{0x100000 + 64 * i:x}"
                               for i in range(2048)))
        t4k = ingest_trace(str(p), 1, use_cache=False)
        t8k = ingest_trace(str(p), 1, page_bytes=8192, use_cache=False)
        assert np.unique(t4k["vpn"]).size == 32
        assert np.unique(t8k["vpn"]).size == 16
        assert t4k["off"].max() == 63
        assert t8k["off"].max() == 127

    def test_gap_capped_remap_preserves_adjacency(self, tmp_path):
        """Pages adjacent in the address space stay adjacent; a huge
        address-space gap collapses to gap_cap pages."""
        p = tmp_path / "gap.csv"
        addrs = [0x1000 * v for v in (5, 6, 7)] + [0x7f00000000000]
        p.write_text("\n".join(f"0x{a:x}" for a in addrs))
        tr = ingest_trace(str(p), 1, use_cache=False, gap_cap=512)
        assert tr["vpn"][0].tolist() == [0, 1, 2, 2 + 512]
        assert tr["pages"] == 515

    def test_interleave_modes(self, tmp_path):
        p = tmp_path / "i.csv"
        p.write_text("\n".join(f"0x{0x1000 * i:x}" for i in range(8)))
        rr = ingest_trace(str(p), 2, use_cache=False, gap_cap=1)
        assert rr["vpn"].tolist() == [[0, 2, 4, 6], [1, 3, 5, 7]]
        bl = ingest_trace(str(p), 2, use_cache=False, gap_cap=1,
                          interleave="blocked")
        assert bl["vpn"].tolist() == [[0, 1, 2, 3], [4, 5, 6, 7]]
        with pytest.raises(TraceFormatError, match="tid column"):
            ingest_trace(str(p), 2, use_cache=False, interleave="thread")

    def test_length_clamp_and_too_short(self, tmp_path):
        p = tmp_path / "s.csv"
        p.write_text("\n".join(f"0x{0x1000 * i:x}" for i in range(10)))
        tr = ingest_trace(str(p), 2, length=3, use_cache=False)
        assert tr["vpn"].shape == (2, 3)
        with pytest.raises(TraceFormatError, match="too short"):
            ingest_trace(str(p), 16, use_cache=False)

    def test_work_clip(self, tmp_path):
        p = tmp_path / "w.lackey"
        p.write_text("".join("I  04000000,3\n" for _ in range(500))
                     + " L 00001000,8\n L 00002000,8\n")
        tr = ingest_trace(str(p), 1, use_cache=False, work_clip=64)
        assert tr["work"].max() == 64

    def test_bad_options_raise(self, tmp_path):
        p = tmp_path / "a.csv"
        p.write_text("0x1000\n0x2000\n")
        with pytest.raises(ValueError, match="power of two"):
            ingest_trace(str(p), 1, page_bytes=3000, use_cache=False)
        with pytest.raises(ValueError, match="gap_cap"):
            ingest_trace(str(p), 1, gap_cap=0, use_cache=False)
        with pytest.raises(ValueError, match="work_clip"):
            ingest_trace(str(p), 1, work_clip=-5, use_cache=False)
        with pytest.raises(ValueError, match="unknown interleave"):
            ingest_trace(str(p), 1, interleave="zigzag", use_cache=False)
        with pytest.raises(TraceFormatError, match="unknown trace format"):
            ingest_trace(str(p), 1, fmt="elf", use_cache=False)


# ---------------------------------------------------------------------------
# caching
# ---------------------------------------------------------------------------
class TestCache:
    def test_cache_hit_bit_exact_vs_cold_parse(self, tmp_path,
                                               monkeypatch):
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path / "cache"))
        p = write_champsim(tmp_path / "t.champsim.xz", champsim_records())
        cold = ingest_trace(p, 2, length=64)
        files = [f for f in (tmp_path / "cache").iterdir()
                 if not f.name.endswith(".sha256")]
        assert len(files) == 1 and files[0].name.startswith("ingest_")
        warm = ingest_trace(p, 2, length=64)            # served from npz
        nocache = ingest_trace(p, 2, length=64, use_cache=False)
        for k in ("vpn", "off", "work"):
            np.testing.assert_array_equal(cold[k], warm[k])
            np.testing.assert_array_equal(cold[k], nocache[k])
        assert cold["pages"] == warm["pages"] == nocache["pages"]

    def test_cache_key_covers_file_content_and_options(self, tmp_path,
                                                       monkeypatch):
        """Editing the trace file or any pipeline option must miss the
        cache (fresh npz), never serve the stale entry."""
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path / "cache"))
        p = write_champsim(tmp_path / "t.champsim", champsim_records())
        ingest_trace(p, 2, length=64)
        ingest_trace(p, 2, length=64, page_bytes=8192)
        count = lambda: len([f for f in (tmp_path / "cache").iterdir()
                             if not f.name.endswith(".sha256")])
        assert count() == 2
        write_champsim(p, champsim_records(seed=9))     # new content
        ingest_trace(p, 2, length=64)
        assert count() == 3


# ---------------------------------------------------------------------------
# acceptance: fixtures through the engines, cached vs uncached
# ---------------------------------------------------------------------------
def _assert_results_equal(a, b, msg=""):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{msg}: {f.name}")
        else:
            assert va == vb, f"{msg}: {f.name}"


class TestEngineAcceptance:
    def test_fixtures_exist_and_ingest(self):
        for path in (GUPS_FIX, GRAPH_FIX):
            assert os.path.getsize(path) < 200 * 1024
            tr = generate_trace(f"trace:{path}", 2, length=256,
                                use_cache=False)
            assert tr["vpn"].shape == (2, 256)
            assert (tr["vpn"] >= 0).all()
            assert (tr["vpn"] < tr["pages"]).all()
            assert (tr["off"] >= 0).all() and (tr["off"] < 64).all()

    def test_fixture_replay_cached_vs_uncached_bit_exact(self, tmp_path,
                                                         monkeypatch):
        """ISSUE-4 acceptance: the committed fixtures replay through
        simulate_batch and sweep() bit-exactly cached vs uncached."""
        from repro.configs.ndp_sim import ndp_machine
        from repro.sim import simulate_batch, sweep

        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path / "cache"))
        specs = [f"trace:{GUPS_FIX}", f"trace:{GRAPH_FIX}"]
        mach = ndp_machine(2)
        cold = simulate_batch(mach, specs, length=384)   # parses + caches
        warm = simulate_batch(mach, specs, length=384)   # cache npz
        for c, w, s in zip(cold, warm, specs):
            _assert_results_equal(c, w, s)

        grid = {"workload": tuple(specs)}
        r_warm = sweep(grid, cores=2, trace_len=384, chunk=512)
        monkeypatch.setenv("SIM_TRACE_CACHE", "0")
        r_cold = sweep(grid, cores=2, trace_len=384, chunk=512)
        assert r_warm.stats["buckets"] == 1
        for s in specs:
            _assert_results_equal(r_warm.point(workload=s),
                                  r_cold.point(workload=s), s)

    def test_real_trace_beats_radix_with_ndpage(self, tmp_path,
                                                monkeypatch):
        """The paper's effect on a REAL trace: NDPage >= radix."""
        from repro.configs.ndp_sim import ndp_machine
        from repro.sim import simulate

        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        res = simulate(ndp_machine(2), f"trace:{GUPS_FIX}", length=512)
        assert res.speedup_vs()["ndpage"] >= 1.0
        assert res.scalar("tlb_miss_rate", "radix") > 0.5
