"""simulate_batch: bit-exactness vs per-sim simulate, B-axis sharding,
and SimResult slicing helpers."""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.ndp_sim import cpu_machine, ndp_machine
from repro.sim import simulate, simulate_batch
from repro.sim.mechanisms import DEFAULT_MECHS
from repro.workloads import generate_traces

WORKLOADS3 = ("rnd", "bc", "bfs")
LEN = 700          # spans a chunk boundary at chunk=512


def _assert_results_equal(a, b, msg=""):
    """Counter-for-counter equality of two SimResults."""
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{msg}: {f.name}")
        else:
            assert va == vb, f"{msg}: {f.name}"


class TestBitExact:
    """The batch engine must reproduce per-sim simulate() exactly —
    every counter and cycle, not just within tolerance."""

    @pytest.mark.parametrize("cores", [1, 2])
    def test_batch_equals_loop_ndp(self, cores):
        mach = ndp_machine(cores)
        traces = generate_traces(WORKLOADS3, cores, length=LEN, seed=7)
        singles = [simulate(mach, tr, chunk=512) for tr in traces]
        batched = simulate_batch(mach, traces, chunk=512)
        assert len(batched) == len(traces)
        for w, s, b in zip(WORKLOADS3, singles, batched):
            _assert_results_equal(s, b, msg=f"ndp{cores} {w}")

    def test_batch_equals_loop_cpu_with_pl3(self):
        # the CPU hierarchy (L2+L3) and a registered extension mechanism
        # both ride the same batched lanes
        mach = cpu_machine(2)
        names = DEFAULT_MECHS + ("ndpage_pl3",)
        traces = generate_traces(WORKLOADS3[:2], 2, length=LEN, seed=7)
        singles = [simulate(mach, tr, chunk=512, mechs=names)
                   for tr in traces]
        batched = simulate_batch(mach, traces, chunk=512, mechs=names)
        for s, b in zip(singles, batched):
            assert b.mechs == names
            _assert_results_equal(s, b, msg="cpu2+pl3")

    def test_mixed_trace_lengths(self):
        # lanes with different true lengths are masked per-sim
        mach = ndp_machine(1)
        t_long = generate_traces(("rnd",), 1, length=LEN, seed=7)[0]
        t_short = generate_traces(("bc",), 1, length=300, seed=7)[0]
        singles = [simulate(mach, t_long, chunk=512),
                   simulate(mach, t_short, chunk=512)]
        batched = simulate_batch(mach, [t_long, t_short], chunk=512)
        for s, b in zip(singles, batched):
            _assert_results_equal(s, b, msg="mixed-length")
        assert batched[0].accesses == LEN
        assert batched[1].accesses == 300

    def test_empty_batch(self):
        assert simulate_batch(ndp_machine(1), []) == []

    def test_single_core_vs_nonbatched_oracle(self):
        """At 1 core, simulate() reroutes through the batch engine (the
        non-batched width-1 lane reduce reassociates), so the looped-vs-
        batched test above compares the batch engine to itself there.
        This pins the rerouted result against the ORIGINAL non-batched
        engine: integer event counters must match exactly, float cycle
        accumulators to reduction-order tolerance."""
        from repro.sim import simulator as S
        mach = ndp_machine(1)
        tr = generate_traces(("rnd",), 1, length=LEN, seed=7)[0]
        batched = simulate_batch(mach, [tr], chunk=512)[0]
        oracle = S._simulate_single(mach, tr, None, DEFAULT_MECHS, 512)
        float_accum = {"cycles", "trans_cycles", "walk_cycles"}
        for f in dataclasses.fields(oracle):
            va, vb = getattr(oracle, f.name), getattr(batched, f.name)
            if f.name in float_accum:
                np.testing.assert_allclose(va, vb, rtol=1e-6,
                                           err_msg=f.name)
            elif isinstance(va, np.ndarray):
                np.testing.assert_array_equal(va, vb, err_msg=f.name)
            else:
                assert va == vb, f.name


class TestSharding:
    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs >1 XLA host device (SIM_DEVICES)")
    def test_sharded_equals_unsharded(self):
        mach = ndp_machine(2)
        traces = generate_traces(WORKLOADS3, 2, length=LEN, seed=7)
        sharded = simulate_batch(mach, traces, chunk=512,
                                 devices=len(jax.devices()))
        unsharded = simulate_batch(mach, traces, chunk=512, devices=1)
        for s, u in zip(sharded, unsharded):
            _assert_results_equal(s, u, msg="sharded")

    @pytest.mark.slow
    def test_sharded_equals_unsharded_subprocess(self):
        """Force 2 host devices in a fresh process (the in-process test
        above is skipped on default single-device runs)."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=2"
                            ).strip()
        env["SIM_DEVICES"] = "2"
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        code = (
            "import jax, numpy as np\n"
            "assert len(jax.devices()) == 2, jax.devices()\n"
            "from repro.configs.ndp_sim import ndp_machine\n"
            "from repro.sim import simulate_batch\n"
            "from repro.workloads import generate_traces\n"
            "traces = generate_traces(('rnd', 'bc', 'bfs'), 2,"
            " length=700, seed=7)\n"
            "mach = ndp_machine(2)\n"
            "sh = simulate_batch(mach, traces, chunk=512, devices=2)\n"
            "un = simulate_batch(mach, traces, chunk=512, devices=1)\n"
            "for s, u in zip(sh, un):\n"
            "    np.testing.assert_array_equal(s.cycles, u.cycles)\n"
            "    np.testing.assert_array_equal(s.walks, u.walks)\n"
            "print('SHARD_OK')\n"
        )
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=560)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARD_OK" in out.stdout


class TestSelect:
    @pytest.fixture(scope="class")
    def res(self):
        mach = ndp_machine(2)
        traces = generate_traces(("rnd",), 2, length=LEN, seed=7)
        return simulate_batch(mach, traces, chunk=512)[0]

    def test_select_mechs_subset_and_order(self, res):
        sub = res.select(mechs=("ndpage", "radix"))
        assert sub.mechs == ("ndpage", "radix")
        np.testing.assert_array_equal(
            sub.cycles[1], res.cycles[res.mechs.index("radix")])

    def test_select_cores(self, res):
        one = res.select(cores=1)
        assert one.cycles.shape == (len(res.mechs), 1)
        np.testing.assert_array_equal(one.instructions,
                                      res.instructions[1:2])
        sl = res.select(cores=slice(0, 2))
        np.testing.assert_array_equal(sl.cycles, res.cycles)

    def test_scalar_matches_raw_indexing(self, res):
        i = res.mechs.index("radix")
        want = float((res.walk_cycles[i] /
                      np.maximum(res.walks[i], 1)).mean())
        assert res.scalar("avg_ptw_latency", "radix") == pytest.approx(want)

    def test_derived_metrics_survive_selection(self, res):
        sub = res.select(mechs=("radix", "ideal"))
        assert sub.speedup_vs("radix")["ideal"] == pytest.approx(
            res.speedup_vs("radix")["ideal"])
