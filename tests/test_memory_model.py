"""MemoryModel: spec semantics, bounded_linear bit-exactness vs the
pre-MemoryModel engine, banked row-buffer locality, per-bank queue
independence, the shape/data split, and the legacy-kwarg shim."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.configs.ndp_sim import MachineConfig, cpu_machine, ndp_machine
from repro.core import page_table as PT
from repro.sim import (MEMORY_MODELS, MemoryModel, apply_param, simulate,
                       simulate_batch, sweep)
from repro.sim import memory_model as mm
from repro.workloads import generate_trace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI has it
    HAVE_HYPOTHESIS = False


def banked(mach: MachineConfig) -> MachineConfig:
    """The machine with its memory switched to the banked preset
    (calibration-preserving, same as the sweep knob)."""
    return apply_param(mach, "memory_model", "banked")


def _assert_results_equal(a, b, msg=""):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{msg}: {f.name}")
        else:
            assert va == vb, f"{msg}: {f.name}"


# ---------------------------------------------------------------------------
# the spec itself
# ---------------------------------------------------------------------------
class TestSpec:
    def test_presets_and_derived_timings(self):
        bl = MEMORY_MODELS["bounded_linear"]
        bk = MEMORY_MODELS["banked"]
        assert bl.miss_latency() == bl.hit_latency() == bl.latency
        assert bl.row_hit_save() == 0.0
        assert bk.miss_latency() == (bk.overhead + bk.t_rp + bk.t_rcd
                                     + bk.t_cas)
        assert bk.hit_latency() == bk.overhead + bk.t_cas
        assert bk.row_hit_save() == bk.t_rp + bk.t_rcd
        # the banked ndp preset is calibrated to the bounded ndp latency
        assert bk.miss_latency() == bk.latency == 100.0

    def test_line_cycles_prices_contiguity(self):
        bk = MEMORY_MODELS["banked"]
        assert bk.line_cycles(contiguous=True) == bk.hit_latency()
        assert bk.line_cycles(contiguous=False) == bk.miss_latency()
        bl = MEMORY_MODELS["bounded_linear"]
        assert (bl.line_cycles(True) == bl.line_cycles(False)
                == bl.latency)

    def test_shape_key_splits_only_on_geometry(self):
        bl = MEMORY_MODELS["bounded_linear"]
        assert bl.shape_key() == ("bounded_linear",)
        # timings are DATA: same shape key
        assert (dataclasses.replace(bl, latency=60.0).shape_key()
                == bl.shape_key())
        bk = MEMORY_MODELS["banked"]
        assert bk.shape_key() == ("banked", 16, 2048)
        assert (dataclasses.replace(bk, t_cas=40.0).shape_key()
                == bk.shape_key())
        assert (dataclasses.replace(bk, num_banks=8).shape_key()
                != bk.shape_key())

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown memory model kind"):
            MemoryModel(kind="open_page")
        with pytest.raises(ValueError, match="num_banks"):
            MemoryModel(kind="banked", num_banks=0)
        with pytest.raises(ValueError, match="row_buffer_bytes"):
            MemoryModel(kind="banked", row_buffer_bytes=100)
        with pytest.raises(ValueError, match="t_cas"):
            MemoryModel(kind="banked", t_cas=-1.0)

    def test_resolve(self):
        assert mm.resolve_memory_model(None) is MEMORY_MODELS[
            "bounded_linear"]
        assert mm.resolve_memory_model("banked") is MEMORY_MODELS["banked"]
        got = mm.resolve_memory_model(dict(latency=60.0))
        assert got.latency == 60.0 and got.kind == "bounded_linear"
        with pytest.raises(KeyError, match="unknown memory model preset"):
            mm.resolve_memory_model("ddr9")
        with pytest.raises(TypeError):
            mm.resolve_memory_model(42)

    def test_with_kind_preserves_calibration(self):
        cpu = cpu_machine(1).memory          # latency 170, bounded
        bk = mm.with_kind(cpu, "banked")
        assert bk.kind == "banked"
        # closed-row total re-calibrated to the cpu's access latency
        assert bk.miss_latency() == pytest.approx(170.0)
        back = mm.with_kind(bk, "bounded_linear")
        assert back.kind == "bounded_linear"
        assert back.latency == 170.0
        # service carries from the CURRENT model (the per-bank service
        # is real calibration too); the no-op switch is a true identity
        assert back.service == bk.service
        assert mm.with_kind(cpu, "bounded_linear") == cpu


# ---------------------------------------------------------------------------
# MachineConfig integration + the legacy shim
# ---------------------------------------------------------------------------
class TestMachineConfig:
    def test_factories_carry_memory_models(self):
        assert ndp_machine(2).memory.latency == 100.0
        assert cpu_machine(2).memory.latency == 170.0
        assert ndp_machine(2).memory.kind == "bounded_linear"

    def test_deprecated_properties_read_through(self):
        mach = ndp_machine(2)
        assert mach.mem_latency == mach.memory.latency
        assert mach.mem_bandwidth_gbs == mach.memory.bandwidth_gbs
        assert mach.mem_service == mach.memory.service

    def test_legacy_kwargs_warn_once_and_fold_into_memory(self):
        mm._WARNED_LEGACY = False
        base = ndp_machine(2)
        with pytest.warns(DeprecationWarning, match="memory"):
            legacy = dataclasses.replace(base, mem_latency=123.0,
                                         mem_service=40.0)
        assert legacy.memory.latency == 123.0
        assert legacy.memory.service == 40.0
        assert legacy.memory.kind == "bounded_linear"
        # second use: silent (one warning per process)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            again = dataclasses.replace(base, mem_latency=60.0)
        assert again.memory.latency == 60.0

    def test_legacy_sweep_path_rewrites(self):
        mm._WARNED_LEGACY = False
        with pytest.warns(DeprecationWarning):
            m = apply_param(ndp_machine(2), "mem_latency", 60.0)
        assert m.memory.latency == 60.0

    def test_unknown_memory_knob_lists_known_knobs(self):
        with pytest.raises(ValueError, match="known knobs are"):
            apply_param(ndp_machine(2), "memory.t_casz", 10.0)
        with pytest.raises(ValueError, match="memory_model"):
            apply_param(ndp_machine(2), "memory.kindz", "banked")
        # nested VALUE overrides still flow through
        m = apply_param(ndp_machine(2), "memory.t_cas", 40.0)
        assert m.memory.t_cas == 40.0

    def test_memory_model_knob_switches_kind(self):
        m = banked(cpu_machine(2))
        assert m.memory.kind == "banked"
        assert m.memory.miss_latency() == pytest.approx(170.0)


# ---------------------------------------------------------------------------
# bounded_linear is bit-exact vs the pre-MemoryModel engine
# ---------------------------------------------------------------------------
#: pinned per-mechanism mean cycles of the default bounded engine,
#: captured on the commit that introduced MemoryModel (the last engine
#: without it produces these EXACT values) — float64 equality, not rtol
PIN_NDP8_RND = [1833050.75, 1702481.0, 2007893.75, 1330220.75,
                651822.0625]
PIN_NDP8_SUMS = (60203748.0, 34130864.0, 31640676.0)
PIN_CPU4_BC = [390846.4375, 351464.78125, 404215.375, 299769.90625,
               169400.359375]
PIN_CPU4_SUM = 6462787.5


class TestBoundedBitExact:
    def test_ndp_pinned(self):
        res = simulate(ndp_machine(8),
                       generate_trace("rnd", 8, length=2048, seed=1234,
                                      preset="smoke"), chunk=512)
        np.testing.assert_array_equal(res.cycles.mean(axis=1),
                                      np.array(PIN_NDP8_RND))
        assert float(res.cycles.sum()) == PIN_NDP8_SUMS[0]
        assert float(res.trans_cycles.sum()) == PIN_NDP8_SUMS[1]
        assert float(res.walk_cycles.sum()) == PIN_NDP8_SUMS[2]

    def test_cpu_pinned(self):
        res = simulate(cpu_machine(4),
                       generate_trace("bc", 4, length=1024, seed=7,
                                      preset="smoke"), chunk=256)
        np.testing.assert_array_equal(res.cycles.mean(axis=1),
                                      np.array(PIN_CPU4_BC))
        assert float(res.cycles.sum()) == PIN_CPU4_SUM


# ---------------------------------------------------------------------------
# row-buffer locality at the address-mapping level
# ---------------------------------------------------------------------------
def _row_hit_fraction(lines: np.ndarray, model: MemoryModel) -> float:
    """Fraction of accesses that find their bank's row open, replaying
    ``lines`` in order against per-bank last-row state — the numpy twin
    of the engine's carried ``bank_row`` tables."""
    banks = np.asarray(mm.bank_of(lines, model.num_banks,
                                  model.lines_per_row))
    rows = np.asarray(mm.row_of(lines, model.num_banks,
                                model.lines_per_row))
    open_row = {}
    hits = 0
    for b, r in zip(banks.tolist(), rows.tolist()):
        hits += open_row.get(b) == r
        open_row[b] = r
    return hits / len(banks)


class TestRowBufferLocality:
    def test_flat_span_walk_hits_radix_node_allocations_miss(self):
        # the structural claim at allocation granularity: the flat
        # table's leaf span is ONE contiguous line run, so walking it
        # streams through open rows; the radix tree allocates each leaf
        # node independently (hash-scattered bases), so stepping from
        # node to node lands on a fresh row every time
        model = MEMORY_MODELS["banked"]
        span_vpns = np.arange(0, 1 << 15, 8, dtype=np.int64)
        flat = np.asarray(PT.ndpage_walk_lines(span_vpns))[:, -1]
        assert (np.diff(flat) == 1).all()    # one contiguous run
        node_vpns = np.arange(256, dtype=np.int64) * 512
        radix = np.asarray(PT.radix4_walk_lines(node_vpns))[:, -1]
        f_flat = _row_hit_fraction(flat, model)
        f_radix = _row_hit_fraction(radix, model)
        assert f_flat > 0.9, f_flat
        assert f_radix < 0.1, f_radix

    def test_mapping_round_trip(self):
        model = MEMORY_MODELS["banked"]
        lines = np.arange(10 * model.num_banks * model.lines_per_row)
        banks = mm.bank_of(lines, model.num_banks, model.lines_per_row)
        rows = mm.row_of(lines, model.num_banks, model.lines_per_row)
        # every (bank, row) pair holds exactly lines_per_row lines
        pair = banks * (rows.max() + 1) + rows
        _, counts = np.unique(pair, return_counts=True)
        assert (counts == model.lines_per_row).all()

    def test_row_hits_save_cycles_end_to_end(self):
        # neutralize ONLY the row-hit save (t_rp = t_rcd = 0, overhead
        # bumped so the closed-row total stays 100 cycles): the machine
        # with the save enabled must never be slower, and strictly
        # faster for ndpage — proof the engine's carried bank_row state
        # actually fires on the flat-leaf/data line streams.  All
        # value-only: both runs share one compiled runner.
        mach = banked(ndp_machine(2))
        nosave = mach
        for p, v in (("memory.t_rp", 0.0), ("memory.t_rcd", 0.0),
                     ("memory.overhead", 75.0)):
            nosave = apply_param(nosave, p, v)
        assert (nosave.memory.miss_latency()
                == mach.memory.miss_latency() == 100.0)
        tr = generate_trace("xs", 2, length=512, seed=3, preset="smoke")
        with_save = simulate(mach, tr, chunk=512)
        without = simulate(nosave, tr, chunk=512)
        diff = without.cycles.mean(axis=1) - with_save.cycles.mean(axis=1)
        assert (diff >= 0.0).all(), diff
        assert diff[with_save.mechs.index("ndpage")] > 0.0, diff


# ---------------------------------------------------------------------------
# per-bank queue independence
# ---------------------------------------------------------------------------
class TestBankQueue:
    def test_bank_queues_are_independent(self):
        # per-(mech, bank) rates: perturbing bank 0's load must leave
        # every other bank's queue delay bit-identical
        rate = np.full((3, 8), 1.0 / 200.0)
        base = np.asarray(mm.queue_delay(rate, 117.0))
        hot = rate.copy()
        hot[:, 0] *= 10.0
        after = np.asarray(mm.queue_delay(hot, 117.0))
        assert (after[:, 0] >= base[:, 0]).all()
        np.testing.assert_array_equal(after[:, 1:], base[:, 1:])

    def test_queue_delay_saturates(self):
        lo = float(np.asarray(mm.queue_delay(1e-9, 100.0)))
        hi = float(np.asarray(mm.queue_delay(1e9, 100.0)))
        assert lo == pytest.approx(0.0, abs=1e-3)
        assert hi == pytest.approx(100.0 * mm.RHO_MAX * mm.QUEUE_K)


# ---------------------------------------------------------------------------
# shape/data split + batch bit-exactness (the sweep engine contract)
# ---------------------------------------------------------------------------
class TestShapeDataSplit:
    # chunks no other test uses: the runner cache entries are cold, so
    # compile counts are attributable to THIS grid
    CHUNK_TIMING = 416
    CHUNK_BANKS = 448

    def test_timing_sweep_is_one_bucket_one_compile(self):
        r = sweep({"memory_model": ("banked",),
                   "memory.t_cas": (15.0, 40.0),
                   "memory.t_rp": (20.0, 30.0),
                   "workload": ("rnd",)},
                  base="ndp", cores=2, trace_len=320,
                  chunk=self.CHUNK_TIMING)
        assert r.stats["buckets"] == 1
        assert r.stats["runner_compiles"] == 1
        # and t_cas actually moved the numbers (the lanes are not
        # accidentally aliased)
        cyc = r.map(lambda x: float(x.cycles.sum()))
        assert (np.diff(cyc, axis=1) > 0).all()

    def test_bank_geometry_is_shape(self):
        r = sweep({"memory_model": ("banked",),
                   "memory.num_banks": (8, 16),
                   "workload": ("rnd",)},
                  base="ndp", cores=2, trace_len=320,
                  chunk=self.CHUNK_BANKS)
        assert r.stats["buckets"] == 2
        assert r.stats["runner_compiles"] == 2

    def test_banked_single_vs_batch_bit_exact(self):
        mach = banked(ndp_machine(2))
        traces = [generate_trace(w, 2, length=700, seed=7, preset="smoke")
                  for w in ("rnd", "bc")]
        singles = [simulate(mach, tr, chunk=512) for tr in traces]
        batched = simulate_batch(mach, traces, chunk=512)
        for s, b in zip(singles, batched):
            _assert_results_equal(s, b, msg="banked batch")


# ---------------------------------------------------------------------------
# total latency is monotone in t_cas
# ---------------------------------------------------------------------------
def _banked_cycles(t_cas: float) -> float:
    # ONE chunk (trace_len == chunk): no cross-chunk queue feedback, so
    # monotonicity in t_cas is strict, not just statistical
    mach = apply_param(banked(ndp_machine(2)), "memory.t_cas",
                       float(t_cas))
    tr = generate_trace("rnd", 2, length=256, seed=11, preset="smoke")
    return float(simulate(mach, tr, chunk=256).cycles.sum())


class TestMonotoneInTcas:
    @pytest.mark.parametrize("lo,hi", [(5.0, 25.0), (25.0, 60.0)])
    def test_monotone_fixed_points(self, lo, hi):
        assert _banked_cycles(lo) < _banked_cycles(hi)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=10, deadline=None)
        @given(lo=st.floats(1.0, 80.0), delta=st.floats(0.5, 40.0))
        def test_monotone_property(self, lo, delta):
            # t_cas is value-only data: every example reuses ONE
            # compiled runner
            assert _banked_cycles(lo) <= _banked_cycles(lo + delta)
