"""MechanismSpec registry: invariants, extension, and smoke regressions."""
import numpy as np
import pytest

from repro.configs import ndp_sim
from repro.configs.ndp_sim import ndp_machine
from repro.sim import mechanisms as MS


class TestSpecTable:
    def test_default_set_matches_paper_order(self):
        assert MS.DEFAULT_MECHS == ("radix", "ech", "hugepage", "ndpage",
                                    "ideal")
        # configs re-exports the registry's tuple — one source of truth
        assert ndp_sim.MECHANISMS == MS.DEFAULT_MECHS

    def test_paper_semantics(self):
        t = MS.tables_for(MS.DEFAULT_MECHS)
        # walk depth: x86 radix 4; ECH d=2 probes; hugepage/ndpage 3;
        # ideal performs no translation at all
        assert t.n_pte.tolist() == [4, 2, 3, 3, 0]
        # only ECH probes in parallel
        assert t.parallel.tolist() == [False, True, False, False, False]
        # only NDPage bypasses the L1 for PTE accesses (observation A)
        assert t.bypass.tolist() == [False, False, False, True, False]
        # only hugepage triggers the fragmentation/promotion model
        assert t.huge.tolist() == [False, False, True, False, False]
        assert t.ideal.tolist() == [False, False, False, False, True]
        # PWCs: radix all 4 levels; hugepage upper 3; ndpage the
        # near-ideal L4/L3 only; ECH and ideal none
        assert t.pwc_on.astype(int).tolist() == [
            [1, 1, 1, 1], [0, 0, 0, 0], [1, 1, 1, 0], [1, 1, 0, 0],
            [0, 0, 0, 0]]

    def test_walking_specs_have_walk_fns(self):
        for name in MS.registered_names():
            spec = MS.get(name)
            assert (spec.walk_fn is None) == (spec.n_pte == 0), name

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            MS.MechanismSpec(name="bad", n_pte=5)
        with pytest.raises(ValueError):
            MS.MechanismSpec(name="bad", n_pte=2,
                             pwc_levels=(True, True, True, False),
                             walk_fn=lambda v: v)
        with pytest.raises(ValueError):
            MS.register(MS.get("radix"))        # duplicate name

    def test_tables_cached_per_name_tuple(self):
        assert MS.tables_for(MS.DEFAULT_MECHS) is MS.tables_for(
            MS.DEFAULT_MECHS)


class TestExtension:
    """Adding a mechanism is one registered dataclass — simulate() picks
    it up via the ``mechs`` tuple without touching the engine."""

    def test_pl3_variant_simulates(self, smoke_sim):
        names = MS.DEFAULT_MECHS + ("ndpage_pl3",)
        res = smoke_sim("rnd", ndp_machine(2), mechs=names)
        assert res.mechs == names
        sp = res.speedup_vs()
        # the flattened-PL3 walk is the shortest non-ideal walk: it must
        # beat radix and not beat ideal
        assert sp["ndpage_pl3"] > 1.05
        assert sp["ndpage_pl3"] < sp["ideal"]
        # 2-access walk -> lower avg walk latency than 3-access ndpage
        ptw = res.avg_ptw_latency()
        assert ptw[names.index("ndpage_pl3")] < ptw[names.index("ndpage")]


class TestSmokeRegression:
    """Pins the smoke-preset cycle ordering the paper's figures rest on."""

    @pytest.fixture(scope="class")
    def res8(self, smoke_sim):
        return smoke_sim("rnd", ndp_machine(8))

    def test_mech_ordering_8core(self, res8):
        cyc = dict(zip(res8.mechs, res8.cycles.mean(axis=1)))
        assert cyc["ideal"] < cyc["ndpage"] < cyc["radix"]
        # 8 cores: fragmentation makes huge pages lose to radix (Fig. 14)
        assert cyc["hugepage"] > cyc["radix"]

    def test_speedup_bands_8core(self, res8):
        sp = res8.speedup_vs()
        assert 1.1 < sp["ndpage"] < 2.5
        assert sp["ideal"] > sp["ndpage"]
        assert sp["hugepage"] < 1.0

    def test_pinned_cycles_8core(self, res8):
        # regression pin for the fixed-seed smoke preset: loose enough to
        # survive float reassociation, tight enough to catch model drift
        got = res8.cycles.mean(axis=1)
        want = PINNED_SMOKE_RND_8C
        np.testing.assert_allclose(got, want, rtol=0.05)


# mean cycles per mechanism, smoke preset, workload "rnd", ndp_machine(8),
# mechanism order = DEFAULT_MECHS.  Regenerate (after an intentional model
# change) with:
#   PYTHONPATH=src python -c "
#   from repro.configs.ndp_sim import ndp_machine, PRESETS
#   from repro.sim import simulate
#   from repro.workloads import generate_trace
#   p = PRESETS['smoke']
#   r = simulate(ndp_machine(8), generate_trace('rnd', 8, preset=p),
#                chunk=p.chunk)
#   print(r.cycles.mean(axis=1).tolist())"
PINNED_SMOKE_RND_8C = [1833050.8, 1702481.0, 2007893.8, 1330220.8, 651822.1]
