"""Unit tests for the sim-facing page-table walk models (repro.core)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import page_table as PT


def vpns(n=1000, hi=1 << 21, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(0, hi, n),
                       jnp.int32)


def test_radix_walk_shape_and_region():
    a = PT.radix4_walk_lines(vpns())
    assert a.shape == (1000, 4)
    assert bool((a >= PT.PT_REGION_LINE).all())


def test_ndpage_walk_is_three_accesses():
    a = PT.ndpage_walk_lines(vpns())
    assert a.shape == (1000, 3)


def test_hugepage_walk_is_three_accesses():
    assert PT.hugepage_walk_lines(vpns()).shape == (1000, 3)


def test_ech_probes_parallel_ways():
    assert PT.ech_probe_lines(vpns()).shape == (1000, 2)


def test_radix_upper_levels_shared_across_neighbours():
    """Adjacent VPNs share L4/L3/L2 nodes and differ only at the leaf."""
    v = jnp.asarray([1000, 1001], jnp.int32)
    a = np.asarray(PT.radix4_walk_lines(v))
    assert (a[0, :3] == a[1, :3]).all()
    # leaf PTEs of adjacent pages share a cache line too (8 PTEs / line)
    assert a[0, 3] == a[1, 3]
    v2 = jnp.asarray([1000, 1000 + 8], jnp.int32)  # crosses the line
    a2 = np.asarray(PT.radix4_walk_lines(v2))
    assert a2[0, 3] != a2[1, 3]


def test_ndpage_flat_level_spans_18_bits():
    """VPNs in the same 2^18 region hit the same flattened node."""
    v = jnp.asarray([5, (1 << 18) - 1, 1 << 18], jnp.int32)
    a = np.asarray(PT.ndpage_walk_lines(v))
    node = a[:, 2] - (a[:, 2] - PT.PT_REGION_LINE) % PT.FLAT_LINES
    assert node[0] == node[1]


def test_occupancy_full_footprint_matches_paper_structure():
    """Dense footprints: PL1/PL2 nearly full, PL3/PL4 nearly empty (Fig 8)."""
    v = np.arange(0, 1 << 21)  # 8GB contiguous footprint
    l4, l3, l2, l1 = PT.occupancy_by_level(v)
    assert l1 > 0.95 and l2 > 0.95
    assert l4 < 0.05 and l3 < 0.05
    assert PT.flattened_occupancy(v) > 0.95


def test_occupancy_sparse_footprint():
    v = np.arange(0, 1 << 21, 512)  # one page per PL1 table
    l4, l3, l2, l1 = PT.occupancy_by_level(v)
    assert l1 < 0.05
