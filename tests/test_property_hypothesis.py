"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="install requirements-dev.txt for the property-test lane")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager
from repro.kernels import ref
from repro.sim import cache_model as CM

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# cache model: inclusion & capacity invariants
# ---------------------------------------------------------------------------
@SET
@given(keys=st.lists(st.integers(0, 63), min_size=1, max_size=60),
       sets_=st.sampled_from([1, 2, 4]), ways=st.sampled_from([1, 2, 4]))
def test_cache_hit_implies_previously_inserted(keys, sets_, ways):
    state = CM.make(sets_, ways)
    seen = set()
    t = jnp.asarray(True)
    for k in keys:
        state, hit = CM.access(state, jnp.asarray(k, jnp.int32),
                               insert=t, enabled=t)
        if bool(hit):
            assert k in seen
        seen.add(k)


@SET
@given(keys=st.lists(st.integers(0, 1000), min_size=1, max_size=50))
def test_cache_never_exceeds_capacity(keys):
    sets_, ways = 2, 2
    state = CM.make(sets_, ways)
    t = jnp.asarray(True)
    for k in keys:
        state, _ = CM.access(state, jnp.asarray(k, jnp.int32),
                             insert=t, enabled=t)
    assert int((state["tags"] > 0).sum()) <= sets_ * ways


# ---------------------------------------------------------------------------
# block tables: flat <-> radix isomorphism for arbitrary mappings
# ---------------------------------------------------------------------------
@SET
@given(data=st.data(),
       b=st.integers(1, 4), maxp=st.sampled_from([4, 8, 16]),
       leaf=st.sampled_from([2, 4]))
def test_radix_flat_isomorphism(data, b, maxp, leaf):
    rng_seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(rng_seed)
    flat = np.full((b, maxp), -1, np.int32)
    for i in range(b):
        n = rng.integers(0, maxp + 1)
        flat[i, :n] = rng.choice(10_000, n, replace=False)
    flat_j = jnp.asarray(flat)
    radix = BT.radix_from_flat(flat_j, leaf_size=leaf)
    merged = np.asarray(BT.flatten_radix(radix))
    assert (merged == flat).all()


# ---------------------------------------------------------------------------
# paged attention: physical placement invariance (THE NDPage invariant)
# ---------------------------------------------------------------------------
@SET
@given(seed=st.integers(0, 2**16), page=st.sampled_from([4, 8]),
       maxp=st.sampled_from([2, 4]))
def test_paged_attention_placement_invariance(seed, page, maxp):
    b, h, kh, d = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed % 1000), 3)
    n = b * maxp + 1
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kp = jax.random.normal(ks[1], (n, page, kh, d))
    vp = jax.random.normal(ks[2], (n, page, kh, d))
    rng = np.random.default_rng(seed)
    tab = np.full((b, maxp), -1, np.int32)
    lens = np.zeros((b,), np.int32)
    pool = list(rng.permutation(n))
    for i in range(b):
        lens[i] = rng.integers(1, maxp * page + 1)
        used = -(-int(lens[i]) // page)
        tab[i, :used] = [pool.pop() for _ in range(used)]
    out1 = ref.paged_attention_ref(q, kp, vp, jnp.asarray(tab),
                                   jnp.asarray(lens))
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    tab2 = np.where(tab >= 0, inv[np.maximum(tab, 0)], -1).astype(np.int32)
    out2 = ref.paged_attention_ref(q, kp[perm], vp[perm],
                                   jnp.asarray(tab2), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# allocator: pages are never shared between live sequences
# ---------------------------------------------------------------------------
@SET
@given(ops=st.lists(st.tuples(st.sampled_from(["add", "append", "free"]),
                              st.integers(0, 3)), min_size=1, max_size=40))
def test_allocator_no_aliasing(ops):
    kvm = KVPageManager(num_pages=128, page_size=4, max_seqs=4, max_len=64)
    live = set()
    for op, sid in ops:
        try:
            if op == "add" and sid not in live:
                kvm.add_sequence(sid, prompt_len=3)
                live.add(sid)
            elif op == "append" and sid in live:
                kvm.append_token(sid)
            elif op == "free" and sid in live:
                kvm.free_sequence(sid)
                live.remove(sid)
        except MemoryError:
            pass
        allocated = [p for s in live for p in kvm.pages[s]]
        assert len(allocated) == len(set(allocated))


# ---------------------------------------------------------------------------
# online softmax (blockwise) == full softmax for arbitrary chunking
# ---------------------------------------------------------------------------
@SET
@given(seed=st.integers(0, 1000), chunks=st.sampled_from([16, 32, 64]))
def test_online_softmax_chunking_invariance(seed, chunks):
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = blockwise_attention(q, k, v, causal=True, q_chunk=chunks,
                              kv_chunk=chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# design-space search: Pareto frontier law over arbitrary objectives
# ---------------------------------------------------------------------------
@SET
@given(vals=st.lists(st.tuples(st.floats(0, 1, allow_nan=False),
                               st.floats(0, 1, allow_nan=False),
                               st.floats(0, 1, allow_nan=False)),
                     min_size=1, max_size=25))
def test_pareto_frontier_membership_iff_nondominated(vals):
    """pareto_indices returns EXACTLY the non-dominated vectors: every
    member is undominated, every non-member has a dominator."""
    from repro.sim._search import OBJECTIVES, dominates, pareto_indices
    names = [n for n, _ in OBJECTIVES]
    vecs = [dict(zip(names, row)) for row in vals]
    front = set(pareto_indices(vecs))
    assert front
    for i, v in enumerate(vecs):
        dominated = any(dominates(w, v)
                        for j, w in enumerate(vecs) if j != i)
        assert (i in front) == (not dominated)


# ---------------------------------------------------------------------------
# zoo translation structures: range-table binary search & inverted hash
# ---------------------------------------------------------------------------
@SET
@given(spans=st.lists(st.tuples(st.integers(1, 50),      # range length
                                st.integers(0, 30)),     # gap after it
                      min_size=1, max_size=20),
       targets=st.lists(st.integers(0, 10**6), min_size=20,
                        max_size=20),
       probes=st.lists(st.integers(-10, 2000), min_size=1,
                       max_size=40),
       base=st.integers(0, 1000))
def test_range_table_binary_search_matches_linear_oracle(
        spans, targets, probes, base):
    """The searchsorted lookup (the production range-walk shape) and
    the O(ranges) linear scan agree on EVERY address — inside a range,
    in a gap, before the first, after the last."""
    from repro.core.page_table import (range_table_lookup,
                                       range_table_lookup_linear)
    starts, lengths = [], []
    pos = base
    for length, gap in spans:
        starts.append(pos)
        lengths.append(length)
        pos += length + gap + 1        # +1 keeps ranges non-overlapping
    starts = np.asarray(starts)
    lengths = np.asarray(lengths)
    tgt = np.asarray(targets[:len(starts)])
    addrs = np.asarray(probes) + base
    fast = range_table_lookup(starts, lengths, tgt, addrs)
    slow = range_table_lookup_linear(starts, lengths, tgt, addrs)
    np.testing.assert_array_equal(fast, slow)


@SET
@given(vpns=st.lists(st.integers(0, 2**31 - 1), min_size=1,
                     max_size=120, unique=True),
       log2_slots=st.sampled_from([7, 8, 10]))
def test_inverted_table_never_aliases_silently(vpns, log2_slots):
    """Open-addressed insert invariants: no two live vpns ever share a
    slot, and a vpn pays extra probes IFF its home slot was taken —
    collisions are never free and never silent."""
    from repro.core.page_table import _hash_np, inverted_table_insert
    vpns = np.asarray(vpns, np.int64)
    slots, probes = inverted_table_insert(vpns, log2_slots=log2_slots)
    assert len(np.unique(slots)) == len(slots)          # no aliasing
    homes = _hash_np(vpns) & np.uint32((1 << log2_slots) - 1)
    displaced = slots != homes.astype(np.int64)
    np.testing.assert_array_equal(probes > 0, displaced)
    # linear probing: the probe count is exactly the slot displacement
    # distance (mod table size)
    dist = (slots - homes.astype(np.int64)) % (1 << log2_slots)
    np.testing.assert_array_equal(probes, dist)
