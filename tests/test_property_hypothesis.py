"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="install requirements-dev.txt for the property-test lane")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager
from repro.kernels import ref
from repro.sim import cache_model as CM

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# cache model: inclusion & capacity invariants
# ---------------------------------------------------------------------------
@SET
@given(keys=st.lists(st.integers(0, 63), min_size=1, max_size=60),
       sets_=st.sampled_from([1, 2, 4]), ways=st.sampled_from([1, 2, 4]))
def test_cache_hit_implies_previously_inserted(keys, sets_, ways):
    state = CM.make(sets_, ways)
    seen = set()
    t = jnp.asarray(True)
    for k in keys:
        state, hit = CM.access(state, jnp.asarray(k, jnp.int32),
                               insert=t, enabled=t)
        if bool(hit):
            assert k in seen
        seen.add(k)


@SET
@given(keys=st.lists(st.integers(0, 1000), min_size=1, max_size=50))
def test_cache_never_exceeds_capacity(keys):
    sets_, ways = 2, 2
    state = CM.make(sets_, ways)
    t = jnp.asarray(True)
    for k in keys:
        state, _ = CM.access(state, jnp.asarray(k, jnp.int32),
                             insert=t, enabled=t)
    assert int((state["tags"] > 0).sum()) <= sets_ * ways


# ---------------------------------------------------------------------------
# block tables: flat <-> radix isomorphism for arbitrary mappings
# ---------------------------------------------------------------------------
@SET
@given(data=st.data(),
       b=st.integers(1, 4), maxp=st.sampled_from([4, 8, 16]),
       leaf=st.sampled_from([2, 4]))
def test_radix_flat_isomorphism(data, b, maxp, leaf):
    rng_seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(rng_seed)
    flat = np.full((b, maxp), -1, np.int32)
    for i in range(b):
        n = rng.integers(0, maxp + 1)
        flat[i, :n] = rng.choice(10_000, n, replace=False)
    flat_j = jnp.asarray(flat)
    radix = BT.radix_from_flat(flat_j, leaf_size=leaf)
    merged = np.asarray(BT.flatten_radix(radix))
    assert (merged == flat).all()


# ---------------------------------------------------------------------------
# paged attention: physical placement invariance (THE NDPage invariant)
# ---------------------------------------------------------------------------
@SET
@given(seed=st.integers(0, 2**16), page=st.sampled_from([4, 8]),
       maxp=st.sampled_from([2, 4]))
def test_paged_attention_placement_invariance(seed, page, maxp):
    b, h, kh, d = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed % 1000), 3)
    n = b * maxp + 1
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kp = jax.random.normal(ks[1], (n, page, kh, d))
    vp = jax.random.normal(ks[2], (n, page, kh, d))
    rng = np.random.default_rng(seed)
    tab = np.full((b, maxp), -1, np.int32)
    lens = np.zeros((b,), np.int32)
    pool = list(rng.permutation(n))
    for i in range(b):
        lens[i] = rng.integers(1, maxp * page + 1)
        used = -(-int(lens[i]) // page)
        tab[i, :used] = [pool.pop() for _ in range(used)]
    out1 = ref.paged_attention_ref(q, kp, vp, jnp.asarray(tab),
                                   jnp.asarray(lens))
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    tab2 = np.where(tab >= 0, inv[np.maximum(tab, 0)], -1).astype(np.int32)
    out2 = ref.paged_attention_ref(q, kp[perm], vp[perm],
                                   jnp.asarray(tab2), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# allocator: pages are never shared between live sequences
# ---------------------------------------------------------------------------
@SET
@given(ops=st.lists(st.tuples(st.sampled_from(["add", "append", "free"]),
                              st.integers(0, 3)), min_size=1, max_size=40))
def test_allocator_no_aliasing(ops):
    kvm = KVPageManager(num_pages=128, page_size=4, max_seqs=4, max_len=64)
    live = set()
    for op, sid in ops:
        try:
            if op == "add" and sid not in live:
                kvm.add_sequence(sid, prompt_len=3)
                live.add(sid)
            elif op == "append" and sid in live:
                kvm.append_token(sid)
            elif op == "free" and sid in live:
                kvm.free_sequence(sid)
                live.remove(sid)
        except MemoryError:
            pass
        allocated = [p for s in live for p in kvm.pages[s]]
        assert len(allocated) == len(set(allocated))


# ---------------------------------------------------------------------------
# online softmax (blockwise) == full softmax for arbitrary chunking
# ---------------------------------------------------------------------------
@SET
@given(seed=st.integers(0, 1000), chunks=st.sampled_from([16, 32, 64]))
def test_online_softmax_chunking_invariance(seed, chunks):
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = blockwise_attention(q, k, v, causal=True, q_chunk=chunks,
                              kv_chunk=chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# design-space search: Pareto frontier law over arbitrary objectives
# ---------------------------------------------------------------------------
@SET
@given(vals=st.lists(st.tuples(st.floats(0, 1, allow_nan=False),
                               st.floats(0, 1, allow_nan=False),
                               st.floats(0, 1, allow_nan=False)),
                     min_size=1, max_size=25))
def test_pareto_frontier_membership_iff_nondominated(vals):
    """pareto_indices returns EXACTLY the non-dominated vectors: every
    member is undominated, every non-member has a dominator."""
    from repro.sim.search import OBJECTIVES, dominates, pareto_indices
    names = [n for n, _ in OBJECTIVES]
    vecs = [dict(zip(names, row)) for row in vals]
    front = set(pareto_indices(vecs))
    assert front
    for i, v in enumerate(vecs):
        dominated = any(dominates(w, v)
                        for j, w in enumerate(vecs) if j != i)
        assert (i in front) == (not dominated)
