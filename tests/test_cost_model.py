"""Translation cost model + costed serving path.

Covers the PR-5 acceptance list: pinned-vs-swept equivalence on one
point, costed translate bit-exactness, tokens/sec ordering stability
across seeds, BENCH_sim.json "serving" merge safety, the trace-cache
memo round-trip, and the TranslationCache version-semantics fixes.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_table as BT
from repro.core.translation_cache import TranslationCache
from repro.sim.cost_model import (ORG_FLAT, ORG_NONE, ORG_RADIX,
                                  PINNED_COSTS, TranslationCostModel,
                                  TranslationMeter, serving_org)


# ---------------------------------------------------------------------------
# cost model derivation
# ---------------------------------------------------------------------------
class TestCostModel:
    def test_pinned_table_loads(self):
        m = TranslationCostModel.pinned()
        assert m.source == "pinned"
        assert m.mechs == tuple(PINNED_COSTS["mechs"])
        assert m.cost("ideal").walk == 0.0
        assert m.cost("ndpage").org == ORG_FLAT
        assert m.cost("radix").org == ORG_RADIX

    def test_pinned_matches_swept_on_the_serving_point(self):
        """The committed table IS a sweep product: re-deriving it from a
        fresh simulator run on the SERVING_COST point must agree (the
        same 5%-band the smoke-figure pins use)."""
        from repro.configs.ndp_sim import SERVING_COST, ndp_machine
        mach = ndp_machine(int(SERVING_COST["cores"]))
        swept = TranslationCostModel.from_sim(mach, use_cache=False)
        pinned = TranslationCostModel.pinned()
        assert swept.mechs == pinned.mechs
        for m in swept.mechs:
            s, p = swept.cost(m), pinned.cost(m)
            assert s.org == p.org, m
            np.testing.assert_allclose(
                [s.tlb_hit, s.walk, s.pte_line],
                [p.tlb_hit, p.walk, p.pte_line], rtol=0.05, atol=1e-9,
                err_msg=f"pinned cost table drifted for {m!r} — "
                        "regenerate with `python -m repro.sim.cost_model`")

    def test_memo_roundtrip(self, tmp_path, monkeypatch):
        """Deriving writes a .trace_cache memo; the second call serves
        it (source='cache') with identical numbers."""
        monkeypatch.setenv("SIM_TRACE_CACHE", str(tmp_path))
        from repro.configs.ndp_sim import ndp_machine
        mach = ndp_machine(2)
        a = TranslationCostModel.from_sim(mach)
        assert a.source == "sweep"
        memos = [f for f in os.listdir(tmp_path)
                 if f.startswith("costmodel_")
                 and not f.endswith(".sha256")]
        assert len(memos) == 1
        # integrity sidecar rides along with the memo
        assert os.path.exists(os.path.join(tmp_path, memos[0] + ".sha256"))
        b = TranslationCostModel.from_sim(mach)
        assert b.source == "cache"
        assert b.costs == a.costs and b.mechs == a.mechs

    def test_walk_ordering_is_paper_consistent(self):
        """The committed costs encode the paper's latency story: ndpage
        walks are cheaper than radix walks, ideal is free."""
        m = TranslationCostModel.pinned()
        assert m.cost("ndpage").walk < m.cost("radix").walk
        assert m.cost("ideal").walk == 0.0

    def test_serving_org_covers_registry(self):
        from repro.sim.cost_model import ORG_INV, ORG_SEG
        from repro.sim.mechanisms import registered_names
        for name in registered_names():
            assert serving_org(name) in (ORG_FLAT, ORG_RADIX, ORG_NONE,
                                         ORG_SEG, ORG_INV)
        assert serving_org("ndpage_pl3") == ORG_FLAT
        assert serving_org("ech") == ORG_RADIX
        # zoo: explicit spec.org overrides win; walkers without one
        # default to the radix tree
        assert serving_org("picorel") == ORG_INV
        assert serving_org("range_table") == ORG_SEG
        assert serving_org("victima") == ORG_RADIX
        assert serving_org("coda") == ORG_RADIX

    def test_lookup_cycles_shape_and_hit_cost(self):
        m = TranslationCostModel.pinned()
        out = m.lookup_cycles(np.array([True, False]),
                              np.array([1, 2]), np.array([2, 4]))
        assert out.shape == (2, len(m.mechs))
        i = m.mechs.index("radix")
        assert out[0, i] == m.cost("radix").tlb_hit
        want = m.cost("radix").walk + 3 * m.cost("radix").pte_line
        assert out[1, i] == pytest.approx(want)
        # flat mechanisms price the FLAT line count
        j = m.mechs.index("ndpage")
        want = m.cost("ndpage").walk + 1 * m.cost("ndpage").pte_line
        assert out[1, j] == pytest.approx(want)


# ---------------------------------------------------------------------------
# costed block-table variants
# ---------------------------------------------------------------------------
def _flat_rows(seed=0, b=4, maxp=32):
    rng = np.random.default_rng(seed)
    flat = np.full((b, maxp), -1, np.int32)
    for i in range(b):
        n = rng.integers(1, maxp + 1)
        flat[i, :n] = rng.permutation(b * maxp)[:n]
    return jnp.asarray(flat)


class TestCostedTranslate:
    def test_costed_translations_bit_exact(self):
        flat = _flat_rows(seed=11)
        radix = BT.radix_from_flat(flat, leaf_size=8)
        for mode, tab in ((BT.FLAT, flat), (BT.RADIX, radix)):
            plain = BT.translate_all(tab, mode)
            costed, lines = BT.translate_all_costed(tab, mode)
            assert (np.asarray(costed) == np.asarray(plain)).all()
            assert np.asarray(lines).shape == (flat.shape[0],)
        seq = jnp.asarray([0, 1, 2, 3])
        page = jnp.asarray([0, 3, 7, 1])
        for mode, tab in ((BT.FLAT, flat), (BT.RADIX, radix)):
            plain = BT.translate_one(tab, seq, page, mode)
            costed, _ = BT.translate_one_costed(tab, seq, page, mode)
            assert (np.asarray(costed) == np.asarray(plain)).all()

    def test_flat_leaves_share_lines_radix_does_not(self):
        """A dense 20-page row spans 2 flat lines (contiguous span) but
        1 directory + 3 leaf lines under radix (each leaf table is its
        own line-aligned node) — Observation B's locality win."""
        flat = np.full((1, 32), -1, np.int32)
        flat[0, :20] = np.arange(20)
        ft = jnp.asarray(flat)
        _, lf = BT.translate_all_costed(ft, BT.FLAT)
        _, lr = BT.translate_all_costed(
            BT.radix_from_flat(ft, leaf_size=8), BT.RADIX)
        assert int(lf[0]) == 2
        assert int(lr[0]) == 1 + 3
        # and generally: flat never touches MORE lines than radix
        rows = _flat_rows(seed=3)
        _, alf = BT.translate_all_costed(rows, BT.FLAT)
        _, alr = BT.translate_all_costed(
            BT.radix_from_flat(rows, leaf_size=8), BT.RADIX)
        assert (np.asarray(alf) <= np.asarray(alr)).all()

    def test_shared_leaf_counted_once(self):
        """A leaf table referenced by two directory entries of one
        sequence (prefix sharing) contributes its lines ONCE."""
        leaves = jnp.asarray(
            np.arange(16, dtype=np.int32).reshape(2, 8))
        shared = BT.RadixTable(
            directory=jnp.asarray([[0, 0, 1, -1]], jnp.int32),
            leaves=leaves)
        unique = BT.RadixTable(
            directory=jnp.asarray([[0, 1, -1, -1]], jnp.int32),
            leaves=leaves)
        n_shared = int(BT.count_pte_lines(shared, BT.RADIX)[0])
        n_unique = int(BT.count_pte_lines(unique, BT.RADIX)[0])
        assert n_shared == n_unique == 1 + 2   # dir line + 2 leaf lines

    def test_translate_one_line_counts(self):
        flat = _flat_rows(seed=5)
        radix = BT.radix_from_flat(flat, leaf_size=8)
        seq = jnp.asarray([0, 1])
        page = jnp.asarray([0, 9])
        _, lf = BT.translate_one_costed(flat, seq, page, BT.FLAT)
        _, lr = BT.translate_one_costed(radix, seq, page, BT.RADIX)
        assert (np.asarray(lf) == 1).all()
        assert (np.asarray(lr) == 2).all()   # dir line + mapped leaf


# ---------------------------------------------------------------------------
# TranslationCache version semantics (PR-5 satellite)
# ---------------------------------------------------------------------------
class TestTranslationCacheVersions:
    def test_hit_rate_zero_on_fresh_cache(self):
        assert TranslationCache().hit_rate == 0.0

    def test_invalidate_bumps_version(self):
        c = TranslationCache()
        c.insert("s", None, np.arange(4))
        assert c.lookup("s") is not None
        c.invalidate("s")
        assert c.version("s") == 1
        # a reused seq id starting over can never see the stale row
        assert c.lookup("s") is None

    def test_stale_row_unreachable_after_bump(self):
        c = TranslationCache()
        c.insert("s", None, np.zeros(2))
        c.bump("s")
        assert c.lookup("s") is None          # version moved on
        c.insert("s", None, np.ones(2))
        row = c.lookup("s")
        assert row is not None and (row == 1).all()

    def test_version_dict_bounded_by_live_set(self):
        """A stream of unique retired seq_ids never grows the version
        dict — invalidate() drops the entry and raises the shared
        floor instead (the long-lived-engine leak regression)."""
        c = TranslationCache(capacity=8)
        for i in range(100):
            c.insert(i, None, np.zeros(1))
            c.bump(i)
            c.invalidate(i)
        assert len(c._versions) == 0
        assert c.version("fresh") >= 100   # floor moved past all of them

    def test_floor_raise_does_not_orphan_live_rows(self):
        """Another sequence retiring must not invalidate a live
        sequence's cached rows (versions are pinned at insert)."""
        c = TranslationCache()
        c.insert("live", None, np.arange(2))
        c.insert("dying", None, np.arange(2))
        c.invalidate("dying")
        assert c.lookup("live") is not None

    def test_explicit_version_keys_still_work(self):
        c = TranslationCache()
        c.insert("s", 7, np.arange(3))
        assert c.lookup("s", 7) is not None
        assert c.lookup("s", 6) is None


# ---------------------------------------------------------------------------
# the costed serving path end-to-end
# ---------------------------------------------------------------------------
class TestCostedServing:
    @pytest.fixture(scope="class")
    def serving_runs(self):
        """The smoke benchmark under two seeds, pinned cost table."""
        from benchmarks.serving_translation import run_serving
        return {seed: run_serving(fast=True, pinned=True, seed=seed)[1]
                for seed in (0, 1)}

    def test_ordering_stable_across_seeds(self, serving_runs):
        for seed, summary in serving_runs.items():
            for mix, s in summary["mixes"].items():
                tps = s["tokens_per_sec"]
                assert tps["ndpage"] >= tps["radix"], (seed, mix)
                assert all(tps["ideal"] >= v - 1e-9
                           for v in tps.values()), (seed, mix)
                assert all(s["checks"].values()), (seed, mix)

    def test_both_mixes_present(self, serving_runs):
        for summary in serving_runs.values():
            assert set(summary["mixes"]) == {"decode_heavy",
                                             "prefill_heavy"}

    def test_serving_merge_never_clobbers(self, tmp_path, serving_runs):
        from benchmarks.serving_translation import merge_into_bench_json
        path = tmp_path / "BENCH_sim.json"
        other = {"figures_wall_s": 1.0, "sweeps": {"pwc_size": {}},
                 "real_traces": {"pairs": {}}}
        path.write_text(json.dumps(other))
        merge_into_bench_json(serving_runs[0], str(path))
        data = json.loads(path.read_text())
        for k, v in other.items():
            assert data[k] == v, k
        assert data["serving"]["mixes"]
        # merging twice just replaces the serving section
        merge_into_bench_json(serving_runs[1], str(path))
        data2 = json.loads(path.read_text())
        assert data2["sweeps"] == other["sweeps"]
        assert data2["serving"]["seed"] == 1

    def test_per_request_budget_sums_to_total(self):
        """The per-request budgets (live + retired) partition the
        meter's total, and retiring keeps the live dict bounded."""
        model = TranslationCostModel.pinned()
        meter = TranslationMeter(model)
        rows = np.asarray(_flat_rows(seed=2, b=3, maxp=16))
        meter.record_step(["a", "b", "c"],
                          np.array([True, False, True]), rows, 16)
        meter.record_step(["a", "b"],
                          np.array([False, True]), rows[:2], 16)
        meter.retire_request("c")
        assert "c" not in meter.per_request
        total = sum(meter.request_budgets().values())
        np.testing.assert_allclose(total, meter.total)
        assert meter.tokens == 5 and meter.steps == 2
        assert meter.hits == 3 and meter.misses == 2
        assert len(meter.step_cycles) == 2
        per_step = meter.per_step_cycles()
        for i, m in enumerate(meter.model.mechs):
            assert per_step[m]["max"] >= per_step[m]["mean"] >= 0.0
            # mean over steps x steps == accumulated total
            assert per_step[m]["mean"] * meter.steps == pytest.approx(
                meter.total[i])

    def test_numpy_fast_path_matches_block_table_helpers(self):
        """The meter's per-step numpy line counting is pinned against
        the canonical jnp helpers (count_pte_lines on the flat table
        and on radix_from_flat)."""
        from repro.sim.cost_model import _np_row_lines
        for seed, ls in ((0, 8), (1, 16), (2, 4)):
            flat = np.asarray(_flat_rows(seed=seed, b=5, maxp=32))
            lf, lr = _np_row_lines(flat, ls)
            want_lf = np.asarray(BT.count_pte_lines(
                jnp.asarray(flat), BT.FLAT))
            want_lr = np.asarray(BT.count_pte_lines(
                BT.radix_from_flat(jnp.asarray(flat), ls), BT.RADIX))
            np.testing.assert_array_equal(lf, want_lf)
            np.testing.assert_array_equal(lr, want_lr)


# ---------------------------------------------------------------------------
# zoo organizations: segment/inverted line accounting
# ---------------------------------------------------------------------------
class TestZooOrgs:
    """Segment (range-descriptor) and inverted (hashed-bucket) PTE-line
    accounting: numpy meter fast path == canonical jnp helpers, and
    lookup_cycles prices each org from ITS line count."""

    CASES = {
        # one contiguous run -> 1 descriptor -> 1 line; inverted pays
        # a bucket line per mapped page
        "contiguous": [0, 1, 2, 3, 4, 5, 6, 7],
        # fully fragmented: every page its own run
        "fragmented": [10, 20, 30, 40, 50, 60, 70, 80],
        # holes split runs; unmapped entries count nowhere
        "holes": [0, 1, -1, 3, 4, -1, -1, 9],
        "empty": [-1] * 8,
        # runs across a hole do NOT merge even when phys is consecutive
        "hole_splits_run": [0, 1, -1, 2, 3, -1, 4, -1],
    }

    def test_numpy_twins_match_block_table(self):
        from repro.sim.cost_model import _np_inv_lines, _np_seg_lines
        flat = np.array(list(self.CASES.values()), np.int32)
        np.testing.assert_array_equal(
            _np_seg_lines(flat),
            np.asarray(BT.count_pte_lines(jnp.asarray(flat),
                                          BT.SEGMENT)))
        np.testing.assert_array_equal(
            _np_inv_lines(flat),
            np.asarray(BT.count_pte_lines(jnp.asarray(flat),
                                          BT.INVERTED)))

    def test_segment_counts_runs_not_pages(self):
        from repro.sim.cost_model import _np_seg_lines
        flat = np.array([self.CASES["contiguous"],
                         self.CASES["fragmented"],
                         self.CASES["holes"],
                         self.CASES["empty"]], np.int32)
        # 1 run -> 1 line; 8 runs -> ceil(8/4)=2 lines; 3 runs -> 1
        # line; no runs -> 0 lines
        np.testing.assert_array_equal(_np_seg_lines(flat), [1, 2, 1, 0])

    def test_inverted_counts_mapped_pages(self):
        from repro.sim.cost_model import _np_inv_lines
        flat = np.array([self.CASES["contiguous"],
                         self.CASES["holes"],
                         self.CASES["empty"]], np.int32)
        np.testing.assert_array_equal(_np_inv_lines(flat), [8, 5, 0])

    def test_lookup_cycles_prices_each_org_from_its_count(self):
        from repro.sim.cost_model import (ORG_INV, ORG_SEG, LookupCost,
                                          TranslationCostModel)
        m = TranslationCostModel(
            mechs=("seg", "inv", "flat"),
            costs=(LookupCost(1.0, 10.0, 2.0, ORG_SEG),
                   LookupCost(1.0, 10.0, 2.0, ORG_INV),
                   LookupCost(1.0, 10.0, 2.0, ORG_FLAT)),
            machine="test", freq_ghz=1.0,
            model_cycles_per_token=100.0, source="pinned")
        assert m.needs_zoo_lines
        hit = np.array([False, False])
        out = m.lookup_cycles(hit, np.array([3, 3]), np.array([5, 5]),
                              lines_seg=np.array([1, 4]),
                              lines_inv=np.array([8, 2]))
        # seg: walk + line*(seg_lines-1); inv likewise; flat from flat
        np.testing.assert_allclose(out[:, 0], [10.0, 10.0 + 2.0 * 3])
        np.testing.assert_allclose(out[:, 1], [10.0 + 2.0 * 7,
                                               10.0 + 2.0 * 1])
        np.testing.assert_allclose(out[:, 2], [10.0 + 2.0 * 2] * 2)
        # omitted zoo counts default to one line (no extra-line cost)
        out2 = m.lookup_cycles(hit, np.array([3, 3]), np.array([5, 5]))
        np.testing.assert_allclose(out2[:, 0], [10.0, 10.0])
        np.testing.assert_allclose(out2[:, 1], [10.0, 10.0])

    def test_paper_model_skips_zoo_accounting(self):
        m = TranslationCostModel.pinned()
        assert not m.needs_zoo_lines
