"""Simulator unit + behaviour tests (cache model, mechanisms ordering)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ndp_sim import cpu_machine, ndp_machine
from repro.sim import cache_model as CM
from repro.sim import simulate
from repro.workloads import generate_trace

T = jnp.asarray(True)
F = jnp.asarray(False)


def key(x):
    return jnp.asarray(x, jnp.int32)


class TestCacheModel:
    def test_miss_then_hit(self):
        st = CM.make(4, 2)
        st, hit = CM.access(st, key(5), insert=T, enabled=T)
        assert not bool(hit)
        st, hit = CM.access(st, key(5), insert=T, enabled=T)
        assert bool(hit)

    def test_lru_eviction(self):
        st = CM.make(1, 2)  # fully assoc, 2 ways
        for k in (1, 2):
            st, _ = CM.access(st, key(k), insert=T, enabled=T)
        st, _ = CM.access(st, key(1), insert=T, enabled=T)   # 1 is MRU
        st, _ = CM.access(st, key(3), insert=T, enabled=T)   # evicts 2
        st, hit1 = CM.access(st, key(1), insert=F, enabled=T)
        st, hit2 = CM.access(st, key(2), insert=F, enabled=T)
        assert bool(hit1) and not bool(hit2)

    def test_disabled_access_is_invisible(self):
        st = CM.make(4, 2)
        st2, hit = CM.access(st, key(9), insert=T, enabled=F)
        assert not bool(hit)
        assert (st2["tags"] == st["tags"]).all()

    def test_set_isolation(self):
        st = CM.make(4, 1)
        st, _ = CM.access(st, key(0), insert=T, enabled=T)   # set 0
        st, _ = CM.access(st, key(1), insert=T, enabled=T)   # set 1
        st, hit = CM.access(st, key(0), insert=F, enabled=T)
        assert bool(hit)


class TestSimulator:
    """Behavioural checks on the smoke preset — the same engine code path
    as full runs (chunked scan, spec registry) at CI-compatible cost."""

    @pytest.fixture(scope="class")
    def result(self, smoke_sim):
        return smoke_sim("rnd", ndp_machine(2))

    def test_ideal_is_fastest(self, result):
        sp = result.speedup_vs()
        assert sp["ideal"] >= max(v for k, v in sp.items() if k != "ideal")

    def test_ndpage_beats_radix_on_ndp(self, result):
        assert result.speedup_vs()["ndpage"] > 1.05

    def test_ndpage_walk_shorter_than_radix(self, result):
        ptw = result.avg_ptw_latency()
        assert ptw[3] < ptw[0]          # ndpage < radix
        assert ptw[4] == 0              # ideal never walks

    def test_pte_l1_missrate_high_on_ndp(self, result):
        # Observation A: PTE accesses can't use the small NDP L1
        assert result.pte_l1_miss_rate()[0] > 0.7

    def test_counters_consistent(self, result):
        assert (result.walks <= result.l1tlb_misses + 1e-6).all()
        assert (result.trans_cycles <= result.cycles).all()

    def test_cpu_less_translation_bound_than_ndp(self, smoke_sim):
        ndp = smoke_sim("bfs", ndp_machine(2))
        cpu = smoke_sim("bfs", cpu_machine(2))
        assert (cpu.translation_fraction()[0]
                < ndp.translation_fraction()[0])

    def test_chunk_padding_invariance(self, smoke):
        # a padded single-chunk run must match an exact-fit single-chunk
        # run entry for entry (both see one queue window, so the only
        # difference is the padding mask)
        trace = generate_trace("rnd", 1, 700, seed=3, preset=smoke)
        exact = simulate(ndp_machine(1), trace, chunk=700)
        padded = simulate(ndp_machine(1), trace, chunk=1024)
        np.testing.assert_allclose(exact.cycles, padded.cycles, rtol=1e-6)
        np.testing.assert_array_equal(exact.walks, padded.walks)
        assert exact.accesses == padded.accesses == 700
