"""KVPageManager: allocation, occupancy-driven flattening, table builds."""
import numpy as np
import pytest

from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager, PagePool


def test_pool_alloc_free():
    pool = PagePool(8)
    a = pool.allocate(5)
    assert len(set(a)) == 5 and pool.free_pages == 3
    pool.release(a[:2])
    assert pool.free_pages == 5
    with pytest.raises(MemoryError):
        pool.allocate(6)


def test_sequence_lifecycle_and_growth():
    kvm = KVPageManager(num_pages=64, page_size=4, max_seqs=4, max_len=64)
    kvm.add_sequence(0, prompt_len=5)       # 2 pages
    assert len(kvm.pages[0]) == 2
    for _ in range(3):
        kvm.append_token(0)                 # 5..8 tokens -> 2 pages
    assert len(kvm.pages[0]) == 2
    kvm.append_token(0)                     # 9 tokens -> 3 pages
    assert len(kvm.pages[0]) == 3
    kvm.free_sequence(0)
    assert kvm.pool.free_pages == 64


def test_occupancy_drives_mode():
    kvm = KVPageManager(num_pages=64, page_size=4, max_seqs=4, max_len=64,
                        flatten_threshold=0.5)
    kvm.add_sequence(0, prompt_len=16)      # 4 full pages -> occupancy 1.0
    assert kvm.preferred_mode() == BT.FLAT
    kvm.add_sequence(1, prompt_len=1)       # 1 token on a 4-slot page
    assert kvm.occupancy() == (16 + 1) / (5 * 4)
    kvm2 = KVPageManager(num_pages=64, page_size=16, max_seqs=4, max_len=64,
                         flatten_threshold=0.5)
    kvm2.add_sequence(0, prompt_len=1)      # 1/16 occupancy
    assert kvm2.preferred_mode() == BT.RADIX


def test_table_build_matches_host_mapping():
    kvm = KVPageManager(num_pages=32, page_size=4, max_seqs=2, max_len=32)
    kvm.add_sequence(7, prompt_len=10)
    kvm.add_sequence(9, prompt_len=3)
    flat = np.asarray(kvm.flat_table([7, 9]))
    assert (flat[0, :3] == kvm.pages[7]).all()
    assert flat[0, 3] == -1
    assert (flat[1, :1] == kvm.pages[9]).all()
    radix = kvm.radix_table([7, 9])
    merged = np.asarray(BT.flatten_radix(radix))
    assert (merged == flat).all()


def test_distinct_sequences_get_distinct_pages():
    kvm = KVPageManager(num_pages=32, page_size=4, max_seqs=4, max_len=32)
    for sid in range(4):
        kvm.add_sequence(sid, prompt_len=8)
    all_pages = sum((kvm.pages[s] for s in range(4)), [])
    assert len(all_pages) == len(set(all_pages))
