import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# the real single CPU device (the dry-run sets 512 itself, in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")
