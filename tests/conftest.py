import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# the real single CPU device (the dry-run sets 512 itself, in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# persist jitted simulator/kernel binaries across test processes: the CI
# fast lane restores this directory so reruns skip XLA compilation
_CACHE_DIR = os.environ.get(
    "REPRO_JAX_CACHE_DIR",
    os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
if _CACHE_DIR:
    jax.config.update("jax_compilation_cache_dir",
                      os.path.abspath(_CACHE_DIR))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


@pytest.fixture(scope="session")
def smoke():
    """The ``smoke`` SimPreset: tiny footprint, short window, fixed seed.

    Tier-1 tests run the full simulator code path through this preset so
    they stay CI-cheap; full-size runs live behind ``-m slow`` /
    benchmarks.
    """
    from repro.configs.ndp_sim import PRESETS
    return PRESETS["smoke"]


@pytest.fixture(scope="session")
def smoke_trace(smoke):
    """generate_trace pinned to the smoke preset: (workload, cores) ->
    trace dict.  Session-cached so test files share trace generation."""
    from repro.workloads import generate_trace
    cache = {}

    def make(workload: str, cores: int):
        key = (workload, cores)
        if key not in cache:
            cache[key] = generate_trace(workload, cores, preset=smoke)
        return cache[key]

    return make


@pytest.fixture(scope="session")
def smoke_sim(smoke, smoke_trace):
    """simulate() pinned to the smoke preset, session-cached per
    (workload, machine) so the jitted runner compiles once per config."""
    from repro.sim import simulate
    cache = {}

    def run(workload: str, mach, **kw):
        key = (workload, mach, tuple(sorted(kw.items())))
        if key not in cache:
            cache[key] = simulate(mach, smoke_trace(workload,
                                                    mach.num_cores),
                                  chunk=smoke.chunk, **kw)
        return cache[key]

    return run
