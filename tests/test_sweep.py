"""sweep(): grid -> shape buckets -> one dispatch per bucket.

Covers the ISSUE-3 edge cases: a 1-point grid is bit-exact vs plain
simulate, shape-bucketing never splits parameter values that share a
shape, SweepResult.select round-trips every named axis, and the
acceptance criterion — a >= 24-point preset compiles at most one runner
per distinct array shape (via the runner cache) with the paper's
sensitivity orderings intact.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.ndp_sim import SWEEPS, ndp_machine
from repro.sim import apply_param, simulate, sweep
from repro.workloads import generate_trace

#: chunk lengths unique to this file so runner-cache accounting below is
#: exact (the cache is keyed on (shape, walk fns, chunk, batched) and
#: shared process-wide; a chunk no other test uses -> fresh keys)
CHUNK_A = 320
CHUNK_B = 352
LEN = 700


def _assert_results_equal(a, b, msg=""):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{msg}: {f.name}")
        else:
            assert va == vb, f"{msg}: {f.name}"


class TestGridEdgeCases:
    def test_one_point_grid_bit_exact_vs_simulate(self):
        """A degenerate 1-point sweep must reproduce plain simulate()
        counter-for-counter — same trace, same chunking, same engine."""
        r = sweep({"workload": ("rnd",)}, cores=2, trace_len=LEN,
                  seed=1234, chunk=512)
        assert r.stats["points"] == 1 and r.stats["buckets"] == 1
        want = simulate(ndp_machine(2),
                        generate_trace("rnd", 2, length=LEN, seed=1234,
                                       preset="smoke"),
                        chunk=512)
        _assert_results_equal(r.point(workload="rnd"), want, "1-point")

    def test_bucketing_never_splits_shared_shapes(self):
        """Value-only axes (memory.latency) must never split a shape
        bucket; shape axes (pwc_entries) split exactly per value."""
        r = sweep({"memory.latency": (100, 140, 170),
                   "pwc_entries": (16, 32),
                   "workload": ("rnd",)},
                  cores=2, trace_len=LEN, chunk=CHUNK_A)
        assert r.stats["points"] == 6
        assert r.stats["buckets"] == 2          # one per pwc_entries value
        # every bucket holds ALL latency variants of its shape
        for b in r.stats["per_bucket"]:
            assert b["lanes"] == 3
            assert b["compiles"] <= 1
        assert r.stats["runner_compiles"] == 2  # fresh chunk -> exact

    def test_value_only_grid_is_one_bucket_one_compile(self):
        r = sweep({"memory.latency": (100, 170),
                   "memory.service": (14.0, 40.0),
                   "workload": ("rnd", "bc")},
                  cores=2, trace_len=LEN, chunk=CHUNK_B)
        assert r.stats["points"] == 8
        assert r.stats["buckets"] == 1
        assert r.stats["runner_compiles"] == 1  # fresh chunk -> exact
        # higher memory latency must not speed anything up
        cyc = r.map(lambda x: float(x.cycles.mean()))
        assert (cyc[1] >= cyc[0]).all()

    def test_unknown_param_and_workload_raise(self):
        with pytest.raises(KeyError, match="no field"):
            sweep({"l1_dtlb.entriez": (32,)}, cores=2, trace_len=LEN)
        with pytest.raises(KeyError, match="unknown workload"):
            sweep({"workload": ("nope",)}, cores=2, trace_len=LEN)
        with pytest.raises(KeyError, match="unknown sweep preset"):
            sweep("not_a_preset")

    def test_apply_param_nested(self):
        m = apply_param(ndp_machine(2), "l1_dtlb.entries", 128)
        assert m.l1_dtlb.entries == 128
        assert m.l1_dtlb.ways == ndp_machine(2).l1_dtlb.ways
        assert ndp_machine(2).l1_dtlb.entries == 64   # original untouched


class TestSelect:
    @pytest.fixture(scope="class")
    def res(self):
        return sweep({"memory.latency": (100, 170),
                      "workload": ("rnd", "bc", "bfs")},
                     cores=2, trace_len=LEN, chunk=512)

    def test_select_round_trips_every_axis(self, res):
        """For every named axis: re-stacking per-value selections
        reproduces the full grid, and selecting the full value list is
        the identity."""
        full = res.scalar("avg_ptw_latency", "radix")
        for dim, (name, vals) in enumerate(res.axes.items()):
            parts = [res.select(**{name: v})
                     for v in vals]                      # scalar: drops axis
            for p in parts:
                assert name not in p.axes
            restacked = np.stack(
                [p.scalar("avg_ptw_latency", "radix") for p in parts],
                axis=dim)
            np.testing.assert_array_equal(restacked, full)
            ident = res.select(**{name: list(vals)})     # list: keeps axis
            assert ident.axes == res.axes
            np.testing.assert_array_equal(
                ident.scalar("avg_ptw_latency", "radix"), full)

    def test_select_subsets_and_reorders(self, res):
        sub = res.select(workload=["bfs", "rnd"])
        assert sub.axes["workload"] == ("bfs", "rnd")
        np.testing.assert_array_equal(
            sub.speedup("ndpage")[:, 1],
            res.select(workload="rnd").speedup("ndpage"))

    def test_point_and_errors(self, res):
        p = res.point(**{"memory.latency": 100, "workload": "bc"})
        assert p.mechs[0] == "radix"
        with pytest.raises(KeyError, match="every axis pinned"):
            res.point(**{"memory.latency": 100})
        with pytest.raises(KeyError, match="unknown sweep axes"):
            res.select(nope=1)
        with pytest.raises(KeyError, match="no value"):
            res.select(**{"memory.latency": 999})

    def test_chained_select_matches_direct_point(self, res):
        a = (res.select(**{"memory.latency": 170})
             .select(workload="bfs").results[()])
        b = res.point(**{"memory.latency": 170, "workload": "bfs"})
        _assert_results_equal(a, b, "chained select")


class TestAcceptance:
    """ISSUE-3 acceptance: >= 24 (machine-variant x workload) points,
    at most one runner compile per distinct array shape, sensitivity
    orderings preserved."""

    def test_mem_latency_preset_24_points_one_compile(self):
        spec = dict(SWEEPS["mem_latency"])
        n_pts = np.prod([len(v) for _, v in spec["axes"]])
        assert n_pts >= 24
        r = sweep("mem_latency", chunk=CHUNK_A)
        assert r.stats["points"] == n_pts
        # pure value grid: every machine variant shares ONE shape, so
        # the whole 24-point sweep is one bucket...
        assert r.stats["buckets"] == 1
        # ...and at most one runner exists per distinct shape (the
        # ndp-4c shape at CHUNK_A was already built by the bucketing
        # test above if it ran first, hence <=)
        assert r.stats["runner_compiles"] <= 1
        assert all(b["compiles"] <= 1 for b in r.stats["per_bucket"])
        # NDPage >= radix at every latency x workload
        assert (r.speedup("ndpage") >= 1.0).all()

    def test_pwc_size_preset_one_compile_per_shape(self):
        r = sweep("pwc_size", chunk=CHUNK_B)
        assert r.stats["points"] >= 24
        n_sizes = len(r.axes["pwc_entries"])
        assert r.stats["buckets"] == n_sizes
        assert r.stats["runner_compiles"] <= n_sizes
        assert all(b["compiles"] <= 1 for b in r.stats["per_bucket"])
        # the paper's ordering: NDPage >= radix at EVERY PWC size
        assert (r.speedup("ndpage") >= 1.0).all()

    def test_bypass_off_degrades_toward_radix(self):
        r = sweep("l1_bypass", chunk=CHUNK_A)
        # ndpage and ndpage_nobyp share walk functions: ONE shape bucket
        assert r.stats["buckets"] == 1
        assert all(b["compiles"] <= 1 for b in r.stats["per_bucket"])
        (mechs_on, mechs_off) = r.axes["mechs"]
        on = r.select(mechs=mechs_on).map(
            lambda x: x.speedup_vs()["ndpage"])
        off = r.select(mechs=mechs_off).map(
            lambda x: x.speedup_vs()["ndpage_nobyp"])
        # the paper's claim shape: averaged over the suite, disabling
        # the bypass degrades NDPage toward radix (it keeps the
        # flattened walk, so it stays above radix).  Per-workload the
        # uniform-probe traces degrade monotonically; the graph traces
        # can gain a little PTE-line reuse from the flattened node's
        # contiguity, which is why the suite mean is the right assert.
        assert off.mean() < on.mean()
        assert (off >= 1.0).all()
        wl = list(r.axes["workload"])
        uni = [wl.index(w) for w in ("rnd", "xs", "dlrm", "gen")]
        assert (off[uni] < on[uni]).all()
