"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


def _paged_inputs(b, h, kh, d, page, maxp, dtype, seed=0, frac=0.7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    n = b * maxp + 2
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kp = jax.random.normal(ks[1], (n, page, kh, d), dtype)
    vp = jax.random.normal(ks[2], (n, page, kh, d), dtype)
    rng = np.random.default_rng(seed)
    tab = np.full((b, maxp), -1, np.int32)
    lens = np.zeros((b,), np.int32)
    perm = rng.permutation(n)
    k = 0
    for i in range(b):
        lens[i] = rng.integers(1, maxp * page + 1)
        used = -(-int(lens[i]) // page)
        tab[i, :used] = perm[k:k + used]
        k += used
    return q, kp, vp, jnp.asarray(tab), jnp.asarray(lens)


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,h,kh,d,page,maxp", [
        (2, 8, 2, 64, 16, 8),       # GQA
        (1, 4, 1, 128, 32, 4),      # MQA
        (3, 4, 4, 32, 8, 16),       # MHA
    ])
    def test_matches_oracle(self, b, h, kh, d, page, maxp, dtype):
        q, kp, vp, tab, lens = _paged_inputs(b, h, kh, d, page, maxp, dtype)
        want = ref.paged_attention_ref(q, kp, vp, tab, lens)
        got = paged_attention_pallas(q, kp, vp, tab, lens, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("window", [8, 40])
    def test_windowed(self, window):
        q, kp, vp, tab, lens = _paged_inputs(2, 4, 2, 64, 16, 6,
                                             jnp.float32, seed=3)
        want = ref.paged_attention_ref(q, kp, vp, tab, lens, window=window)
        got = paged_attention_pallas(q, kp, vp, tab, lens, window=window,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_physical_placement_invariance(self):
        """NDPage core invariant: output independent of WHERE pages live."""
        q, kp, vp, tab, lens = _paged_inputs(2, 4, 2, 64, 8, 4, jnp.float32,
                                             seed=7)
        out1 = ref.paged_attention_ref(q, kp, vp, tab, lens)
        # permute physical pages and rewrite the table accordingly
        n = kp.shape[0]
        perm = np.random.default_rng(1).permutation(n)
        inv = np.argsort(perm)
        kp2 = kp[perm]
        vp2 = vp[perm]
        tab2 = jnp.where(tab >= 0, jnp.asarray(inv)[jnp.maximum(tab, 0)], -1)
        out2 = ref.paged_attention_ref(q, kp2, vp2, tab2, lens)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,s,h,kh,d,bq,bk", [
        (2, 128, 4, 2, 64, 64, 64),
        (1, 256, 8, 8, 32, 64, 128),
        (2, 128, 4, 1, 128, 32, 32),
    ])
    def test_matches_oracle(self, b, s, h, kh, d, bq, bk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), dtype)
        k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
        v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                                     interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("causal,window", [(True, 16), (False, 0)])
    def test_masks(self, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestOpsDispatch:
    def test_cpu_defaults_to_ref(self):
        q, kp, vp, tab, lens = _paged_inputs(1, 2, 1, 32, 8, 2, jnp.float32)
        a = ops.paged_attention(q, kp, vp, tab, lens)
        b = ref.paged_attention_ref(q, kp, vp, tab, lens)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_blockwise_jnp_matches_flash_ref(self):
        """models.attention.blockwise_attention is itself oracle-consistent."""
        from repro.models.attention import blockwise_attention
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 256, 4, 32))
        k = jax.random.normal(ks[1], (2, 256, 2, 32))
        v = jax.random.normal(ks[2], (2, 256, 2, 32))
        want = ref.flash_attention_ref(q, k, v, causal=True, window=50)
        got = blockwise_attention(q, k, v, causal=True, window=50,
                                  q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
