"""Production training launcher.

On a real multi-host TPU fleet this binary runs per host:

  python -m repro.launch.train --arch deepseek-v2-236b --shape train_4k \
      --coordinator $COORD:8476 --num-processes $N --process-id $ID \
      [--multi-pod] [--steps N] [--ckpt-dir gs://...] [--compress-grads]

jax.distributed.initialize() wires the hosts; the mesh/shardings are the
same ones the dry-run proves out (launch.mesh / parallel.sharding).  On
this CPU container use --local-smoke, which runs the identical code path
on a reduced config and a (4,2) host-device mesh.

XLA flags for real runs (latency-hiding overlap of the FSDP/TP collectives
with compute) are set below unless already present in the environment.
"""
import argparse
import os

PROD_XLA_FLAGS = (
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_prod_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--local-smoke", action="store_true",
                    help="reduced config on 8 host devices (CPU container)")
    args = ap.parse_args()

    if args.local_smoke:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    elif "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = PROD_XLA_FLAGS

    import jax

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id)

    import dataclasses

    import jax.numpy as jnp

    from repro import config as C
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    from repro.parallel import sharding as SH
    from repro.train.data import SyntheticLM, add_modality_stubs
    from repro.train.fault_tolerance import FaultConfig, GuardedTrainer
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import init_train_state, make_train_step

    if args.local_smoke:
        cfg = dataclasses.replace(
            C.smoke_variant(C.get_arch(args.arch)), dtype="float32")
        shape = dataclasses.replace(C.SHAPES[args.shape], global_batch=8,
                                    seq_len=64)
        mesh = make_test_mesh(8)
        micro = min(args.microbatches, 2)
    else:
        cfg = C.get_arch(args.arch)
        shape = C.SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        micro = args.microbatches

    compress = None
    if args.compress_grads:
        from repro.parallel.compression import make_dp_int8_allreduce
        compress = make_dp_int8_allreduce(mesh)

    step_fn = make_train_step(cfg, AdamWConfig(total_steps=args.steps),
                              num_microbatches=micro, mesh=mesh,
                              compress=compress)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    p_shard = SH.param_sharding(state.params, mesh, cfg)
    state = state._replace(
        params=jax.device_put(state.params, p_shard),
        opt=jax.device_put(state.opt, {
            "mu": p_shard, "nu": p_shard,
            "step": jax.NamedSharding(mesh,
                                      jax.sharding.PartitionSpec())}))
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch)
    guard = GuardedTrainer(FaultConfig(ckpt_dir=args.ckpt_dir,
                                       ckpt_every=args.ckpt_every),
                           jitted, state)
    guard.install_signal_handler()
    guard.maybe_restore()

    with mesh:
        while guard.step < args.steps:
            raw = add_modality_stubs(
                data.batch_at(guard.step, rank=args.process_id,
                              world=max(args.num_processes, 1)),
                cfg, seed=guard.step)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            metrics = guard.run_step(batch)
            if metrics is None:
                return
            if guard.step % 10 == 0:
                print(f"step {guard.step}: "
                      f"loss={float(metrics['loss']):.4f}")
    print(f"finished {guard.step} steps; stats={guard.stats}")


if __name__ == "__main__":
    main()
