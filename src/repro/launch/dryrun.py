import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: device count locks on first use.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each runnable cell (see repro.config.cells_for) this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (launch.specs — no allocation),
  3. jit-lowers train_step or serve_step with full in/out shardings,
  4. compiles, printing memory_analysis() and cost_analysis(),
  5. parses collective bytes out of the optimized HLO,
  6. appends everything to a JSON results file (incremental cache:
     finished cells are skipped on re-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k [--multi-pod] [--all] [--out dryrun_results.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import config as C
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SP
from repro.models import decode_step, init_decode_state
from repro.parallel import sharding as SH
from repro.roofline.hlo_stats import (collective_bytes, count_collectives,
                                      dot_flops)
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_loop import make_train_step, TrainState

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "dryrun_results.json")

# per-(arch, shape) microbatch counts: keep per-microbatch logits bounded
MICROBATCH = {
    "train_4k": 16,
}


def _microbatches(cfg: C.ArchConfig, shape: C.ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    n = MICROBATCH.get(shape.name, 1)
    return min(n, shape.global_batch)


def lower_train(cfg: C.ArchConfig, shape: C.ShapeConfig, mesh):
    batch_specs = SP.train_input_specs(cfg, shape)
    params = SP.param_specs(cfg)
    p_shard = SH.param_sharding(params, mesh, cfg)
    opt_specs = jax.eval_shape(adamw_init, params)
    o_shard = {
        "mu": p_shard, "nu": p_shard,
        "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
    state_specs = TrainState(params=params, opt=opt_specs, rng=rng_spec)
    state_shard = TrainState(params=p_shard, opt=o_shard, rng=rep)

    batch_axes = SH.batch_axes(mesh)
    b_shard = {
        k: jax.NamedSharding(
            mesh, SH.valid_spec(
                jax.sharding.PartitionSpec(batch_axes), v.shape, mesh))
        for k, v in batch_specs.items()
    }

    step = make_train_step(cfg, AdamWConfig(),
                           num_microbatches=_microbatches(cfg, shape),
                           mesh=mesh)
    jitted = jax.jit(step,
                     in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, rep),
                     donate_argnums=(0,))
    from repro.parallel.context import use_mesh
    with mesh, use_mesh(mesh):
        lowered = jitted.lower(state_specs, batch_specs)
    return lowered


def lower_prefill(cfg: C.ArchConfig, shape: C.ShapeConfig, mesh):
    """Inference prefill: forward pass, last-position logits (KV-fill cost
    is exercised by the serving path; the transformer forward dominates)."""
    from repro.models import forward_train

    batch_specs = SP.train_input_specs(cfg, shape)
    del batch_specs["labels"]
    params = SP.param_specs(cfg)
    p_shard = SH.param_sharding(params, mesh, cfg)
    batch_axes = SH.batch_axes(mesh)
    b_shard = {
        k: jax.NamedSharding(
            mesh, SH.valid_spec(
                jax.sharding.PartitionSpec(batch_axes), v.shape, mesh))
        for k, v in batch_specs.items()
    }
    rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
    out_shard = jax.NamedSharding(
        mesh, SH.valid_spec(jax.sharding.PartitionSpec(batch_axes),
                            (shape.global_batch, cfg.vocab_size), mesh))

    def prefill_step(params, batch):
        logits, _ = forward_train(params, cfg, batch)
        return logits[:, -1]

    jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                     out_shardings=out_shard)
    from repro.parallel.context import use_mesh
    with mesh, use_mesh(mesh):
        lowered = jitted.lower(params, batch_specs)
    return lowered


def lower_serve(cfg: C.ArchConfig, shape: C.ShapeConfig, mesh,
                kv_mode: str = "paged_flat"):
    if cfg.attn_free:
        kv_mode = "dense"
    params = SP.param_specs(cfg)
    state = SP.decode_state_specs(cfg, shape, kv_mode)
    tokens = SP.decode_token_specs(shape)

    # decode params: TP over "model" only — FSDP sharding would re-gather
    # weights over the data axis every step (perf iteration H6)
    serve_cfg = dataclasses.replace(cfg, fsdp=False)
    p_shard = SH.param_sharding(params, mesh, serve_cfg)
    s_shard = SH.state_sharding(state, mesh, cfg)
    batch_axes = SH.batch_axes(mesh)
    t_shard = jax.NamedSharding(
        mesh, SH.valid_spec(jax.sharding.PartitionSpec(batch_axes),
                            (shape.global_batch,), mesh))
    rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def serve_step(params, state, tokens):
        return decode_step(params, cfg, state, tokens, kv_mode=kv_mode)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_shard, s_shard, t_shard),
                     out_shardings=(rep, s_shard),
                     donate_argnums=(1,))
    from repro.parallel.context import use_mesh
    with mesh, use_mesh(mesh):
        lowered = jitted.lower(params, state, tokens)
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             kv_mode: str = "paged_flat") -> Dict[str, Any]:
    cfg = C.get_arch(arch)
    shape = C.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train(cfg, shape, mesh)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, shape, mesh)
    else:
        lowered = lower_serve(cfg, shape, mesh, kv_mode)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_coll = count_collectives(hlo)
    # cost_analysis() visits while bodies ONCE; recover scan-over-layers /
    # grad-accum multiplicity from the HLO loop structure and scale the
    # memory estimate by the same factor (homogeneous loop bodies).
    dots_w, dots_raw = dot_flops(hlo)
    loop_scale = (dots_w / dots_raw) if dots_raw else 1.0
    raw_flops = cost.get("flops", 0.0)
    raw_bytes = cost.get("bytes accessed", 0.0)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "kv_mode": kv_mode if shape.kind == "decode" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": max(dots_w, raw_flops),
        "bytes_accessed": raw_bytes * loop_scale,
        "flops_raw_cost_analysis": raw_flops,
        "bytes_raw_cost_analysis": raw_bytes,
        "dot_flops_weighted": dots_w,
        "dot_flops_unweighted": dots_raw,
        "loop_scale": loop_scale,
        "per_device_memory_bytes": getattr(
            mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0) + getattr(
            mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "generated_code_bytes": getattr(
            mem, "generated_code_size_in_bytes", 0),
        "collective_bytes": coll,
        "collective_counts": n_coll,
    }
    print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
          f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
          f"flops={result['flops']:.3e}  "
          f"hbm/device={result['per_device_memory_bytes']/2**30:.2f}GiB  "
          f"collectives={coll/2**30:.3f}GiB")
    return result


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: Dict[str, Any]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def cell_key(arch: str, shape: str, multi_pod: bool, kv_mode: str) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}|{shape}|{mesh}|{kv_mode}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-mode", default="paged_flat")
    ap.add_argument("--out", default=os.path.abspath(RESULTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = load_results(args.out)
    cells = []
    archs = C.list_archs() if (args.all or not args.arch) else [args.arch]
    for a in archs:
        cfg = C.get_arch(a)
        shapes = (C.cells_for(cfg) if (args.all or not args.shape)
                  else [args.shape])
        for s in shapes:
            meshes = ([False, True] if (args.both_meshes or args.all)
                      else [args.multi_pod])
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        key = cell_key(a, s, mp, args.kv_mode)
        if key in results and not args.force:
            print(f"[dryrun] skip cached {key}")
            continue
        try:
            results[key] = run_cell(a, s, mp, args.kv_mode)
            save_results(args.out, results)
        except Exception as e:
            failures.append((key, repr(e)))
            print(f"[dryrun] FAIL {key}: {e}")
            traceback.print_exc()
    print(f"[dryrun] done: {len(results)} cells cached, "
          f"{len(failures)} failures")
    for k, e in failures:
        print("  FAILED:", k, e)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
