"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
train_step / serve_step against these for every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import config as C
from repro.models import init_decode_state, init_params
from repro.models.model_zoo import DEFAULT_PAGE_SIZE

S = jax.ShapeDtypeStruct


def train_input_specs(cfg: C.ArchConfig, shape: C.ShapeConfig
                      ) -> Dict[str, Any]:
    """Batch specs for a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    s_tok = s - cfg.vision_tokens
    batch: Dict[str, Any] = {
        "tokens": S((b, s_tok), jnp.int32),
        "labels": S((b, s_tok), jnp.int32),
    }
    if cfg.vision_tokens:
        batch["vision_embeds"] = S((b, cfg.vision_tokens, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["audio_frames"] = S((b, cfg.encoder_seq_len, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return batch


def param_specs(cfg: C.ArchConfig) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def decode_state_specs(cfg: C.ArchConfig, shape: C.ShapeConfig,
                       kv_mode: str = "paged_flat",
                       page_size: int = DEFAULT_PAGE_SIZE) -> Any:
    """Decode-state ShapeDtypeStructs for a serve step (cache at seq_len)."""
    if cfg.attn_free:
        kv_mode = "dense"   # no KV path at all; state is O(1) recurrent
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                  kv_mode=kv_mode, page_size=page_size))


def decode_token_specs(shape: C.ShapeConfig) -> Any:
    return S((shape.global_batch,), jnp.int32)
