"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh((devices // 2, 2), ("data", "model"))
