"""Production serving launcher (decode with paged KV + NDPage tables).

Real fleet:
  python -m repro.launch.serve --arch granite-34b --shape decode_32k \
      --kv-mode paged_flat [--multi-pod]

CPU container: --local-smoke serves a reduced config through the full
continuous-batching engine.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--kv-mode", default="paged_flat",
                    choices=["paged_flat", "paged_radix", "dense", "auto"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--local-smoke", action="store_true")
    args = ap.parse_args()

    if args.local_smoke:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    import dataclasses

    import jax
    import numpy as np

    from repro import config as C
    from repro.models import init_params
    from repro.serving import Request, ServeEngine

    if not args.local_smoke:
        raise SystemExit(
            "full-config serving needs TPU hardware; the (arch x shape) "
            "serve_step is proven by `python -m repro.launch.dryrun`; use "
            "--local-smoke here")

    cfg = dataclasses.replace(C.smoke_variant(C.get_arch(args.arch)),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mode = None if args.kv_mode == "auto" else args.kv_mode
    if mode == "dense":
        mode = None
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96, page_size=8,
                      table_mode=mode)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(1, cfg.vocab_size, rng.integers(4, 10))
            .astype(np.int32),
            max_new_tokens=8))
    done = eng.run()
    print(f"served {len(done)} requests; scheduler={eng.sched.stats}; "
          f"tcache={eng.sched.tcache.hit_rate:.2%}")


if __name__ == "__main__":
    main()
