"""Pure-jnp oracles for every Pallas kernel (self-contained, no model deps).

These are the ground truth for the per-kernel allclose sweeps in tests/.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.context import BATCH, constrain_act

NEG_INF = -1e30


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_table: jnp.ndarray,
                        valid_lens: jnp.ndarray, *, window: int = 0
                        ) -> jnp.ndarray:
    """Decode attention over paged KV.

    q: (B, 1, H, D) one query token per sequence
    k_pages/v_pages: (N, page, K, D) physical pools
    block_table: (B, max_pages) int32 physical page ids (-1 = unmapped)
    valid_lens: (B,) number of attendable tokens (incl. the new one)
    window: if > 0, only the last `window` tokens are attendable.
    Returns (B, 1, H, D).
    """
    b, s1, h, d = q.shape
    n, page, kh, _ = k_pages.shape
    g = h // kh
    maxp = block_table.shape[1]
    safe = jnp.maximum(block_table, 0)
    ks = k_pages[safe].reshape(b, maxp * page, kh, d)
    vs = v_pages[safe].reshape(b, maxp * page, kh, d)
    # keep the gathered KV in the pools' sharding (kv-heads or head-dim on
    # "model"); the score einsum then psums small f32 scores instead of
    # all-gathering the cache (H3)
    ks = constrain_act(ks, BATCH, None, "model", "model")
    vs = constrain_act(vs, BATCH, None, "model", "model")

    qg = q.reshape(b, s1, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                        preferred_element_type=jnp.float32)
    scores = constrain_act(scores / math.sqrt(d), BATCH, "model", None,
                           None, None)
    kpos = jnp.arange(maxp * page)
    mask = kpos[None, :] < valid_lens[:, None]
    if window > 0:
        mask &= kpos[None, :] >= (valid_lens[:, None] - window)
    mask &= (block_table >= 0).repeat(page, axis=1)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(vs.dtype), vs,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s1, h, d).astype(q.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0
                        ) -> jnp.ndarray:
    """Full masked softmax attention in f32 (training oracle).

    q: (B, S, H, D); k/v: (B, S, K, D) with GQA grouping H = G*K.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    qpos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= qpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # rows that are fully masked produce uniform weights; zero them
    any_valid = mask.any(axis=1)
    w = jnp.where(any_valid[None, None, None, :, None], w, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, d).astype(q.dtype)


def page_gather_ref(pages: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Materialize logical sequences from paged storage.

    pages: (N, page, F); table: (B, max_pages) -> (B, max_pages*page, F)."""
    safe = jnp.maximum(table, 0)
    b, mp = table.shape
    n, pg, f = pages.shape
    out = pages[safe].reshape(b, mp * pg, f)
    valid = (table >= 0).repeat(pg, axis=1)
    return jnp.where(valid[..., None], out, 0)
