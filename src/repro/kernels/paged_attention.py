"""Pallas TPU paged-attention decode kernel — NDPage's mechanisms on TPU.

The paper's two ideas, re-expressed in the TPU memory hierarchy:

  1. *Flattened table* — the block table is a single-level (B, max_pages)
     int32 map.  The k/v BlockSpec ``index_map`` reads it directly to pick
     which physical page to DMA next: ONE metadata indirection per page,
     not a directory walk.

  2. *Metadata bypass* — the table and sequence lengths are
     **scalar-prefetch operands** (``pltpu.PrefetchScalarGridSpec``): they
     are staged into SMEM for the scalar core ahead of the grid and never
     travel through the HBM->VMEM vector pipeline, so translation metadata
     cannot displace KV tiles from VMEM — the exact analogue of "PTEs
     bypass the L1 and stop polluting the data cache".

Layouts (wrapper-normalized):
  q: (B, KH, G, D)   one decode token per sequence, grouped query heads
  k/v pools: (KH, N, page, D)
  block_table: (B, MAXP) int32 (-1 = unmapped)   [scalar prefetch]
  lengths: (B,) int32 attendable tokens           [scalar prefetch]
Grid: (B, KH, MAXP); online softmax accumulates in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, window: int,
            scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    maxp = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (G, D)
    k = k_ref[0, 0]                                   # (page, D)
    v = v_ref[0, 0]                                   # (page, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, page)

    length = lens_ref[b]
    token_pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = token_pos < length
    if window > 0:
        valid &= token_pos >= length - window
    valid &= table_ref[b, p] >= 0
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # (G, page)
    l_new = l_ref[...] * alpha + pexp.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (G, D)
    acc_new = acc_ref[...] * alpha + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(p == maxp - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, window: int = 0,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, 1, H, D); k/v_pages: (N, page, K, D); block_table: (B, MAXP).

    Returns (B, 1, H, D).  ``interpret=True`` runs the kernel body on CPU
    for validation (this container); on TPU it compiles to Mosaic.
    """
    b, s1, h, d = q.shape
    n, page, kh, _ = k_pages.shape
    g = h // kh
    maxp = block_table.shape[1]
    scale = 1.0 / math.sqrt(d)

    qk = q.reshape(b, kh, g, d)
    kp = k_pages.transpose(2, 0, 1, 3)                # (KH, N, page, D)
    vp = v_pages.transpose(2, 0, 1, 3)

    def q_map(bi, ki, pi, tab, lens):
        return (bi, ki, 0, 0)

    def kv_map(bi, ki, pi, tab, lens):
        return (ki, jnp.maximum(tab[bi, pi], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, 1, page, d), kv_map),
            pl.BlockSpec((1, 1, page, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qk, kp, vp)
    return out.reshape(b, 1, h, d)
