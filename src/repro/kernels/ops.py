"""jit'd public wrappers for the Pallas kernels with pure-jnp fallbacks.

Dispatch policy:
  * on TPU: compiled Pallas kernels
  * REPRO_KERNEL_IMPL=interpret: Pallas in interpret mode (CPU validation)
  * otherwise (this CPU container): the jnp reference oracles

so models/ and serving/ call one API regardless of backend.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas


def _impl(override: Optional[str]) -> str:
    if override:
        return override
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover - device init failure
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, block_table: jnp.ndarray,
                    valid_lens: jnp.ndarray, *, window: int = 0,
                    impl: Optional[str] = None) -> jnp.ndarray:
    """Decode attention over paged KV (see kernels/paged_attention.py)."""
    which = _impl(impl)
    if which == "ref":
        return ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                       valid_lens, window=window)
    return paged_attention_pallas(q, k_pages, v_pages, block_table,
                                  valid_lens, window=window,
                                  interpret=(which == "interpret"))


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: int = 0,
                    impl: Optional[str] = None) -> jnp.ndarray:
    """Blockwise attention (see kernels/flash_attention.py)."""
    which = _impl(impl)
    if which == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(which == "interpret"))
