"""Pallas TPU flash-attention kernel (training/prefill, causal + window).

Blockwise online-softmax attention with GQA head grouping.  Layout:
  q: (B, H, S, D), k/v: (B, KH, S, D)  (wrapper-normalized)
Grid: (B, H, NQ, NK) — NK innermost so the (m, l, acc) scratch carries one
query block's state across KV blocks.

VMEM working set per step = bq*D + 2*bk*D + bq*bk scores; block sizes are
chosen so this sits well under v5e VMEM (~128KB at bq=bk=512, D=128, bf16
inputs with f32 scores/accumulators ~ 1.5MB total) and the MXU sees
(bq x D) @ (D x bk) matmuls with 128-aligned dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 512
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, causal: bool, window: int, scale: float):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (bq, D)
    k = k_ref[0, 0]                                   # (bk, D)
    v = v_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = jnp.ones((bq, bk), bool)
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, D); k/v: (B, S, KH, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(d)

    qt = q.transpose(0, 2, 1, 3)                      # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)                      # (B, KH, S, D)
    vt = v.transpose(0, 2, 1, 3)

    def q_map(bi, hi, qi, ki):
        return (bi, hi, qi, 0)

    def kv_map(bi, hi, qi, ki):
        return (bi, hi // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
