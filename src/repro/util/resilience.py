"""Resilience layer: integrity-checked caches, fault injection, watchdogs.

The repo's long pipelines (trace ingest -> batched sim -> sweep/search
-> costed serving) lean on four on-disk caches that all live in the
trace-cache directory: generated-trace npz files, ingested-trace npz
files, ``costmodel_*.json`` memos and ``search_evals_*.json`` eval
caches.  Before this module each had its own ad-hoc degrade path, and
none could tell a *corrupted* entry from a missing one — a truncated
npz left behind by a killed nightly run crashed the next run instead of
being recomputed.  This module unifies them behind one contract:

  * **Atomic, verified writes** — every entry is written to a temp file
    and renamed into place together with a ``<name>.sha256`` sidecar
    holding the content digest.  Concurrent writers never publish torn
    files; any filesystem failure (read-only checkout, full disk,
    injected OSError) degrades to cache-off, never to a crash.
  * **Verified reads with quarantine** — a read first checks the
    sidecar digest (legacy entries without a sidecar are still parsed,
    but a parse failure is treated the same as a digest mismatch).  A
    corrupted entry is moved to ``<cache-dir>/quarantine/`` — keeping
    the evidence for postmortems while guaranteeing the next run never
    trips over it again — and the caller transparently recomputes.
  * **Recovery visibility** — every degrade decision (quarantine,
    write failure, watchdog retry, checkpoint resume, preemption)
    lands in a bounded process-wide event log
    (:func:`recovery_events`) that benchmark stage summaries and
    ``scripts/chaos.py`` print, so a fault can never heal silently.

Fault injection
---------------
:class:`FaultInjector` replays *deterministic* fault plans against the
instrumented sites so chaos tests can prove end-to-end that injected
faults cost only retries (outputs stay bit-exact vs a fault-free run):

  ``cache_read``   the matching read is treated as corrupt: the entry
                   is quarantined and recomputed
  ``cache_write``  the matching write raises ``OSError`` inside the
                   degrade path: the run continues cache-off
  ``dispatch``     the matching simulator dispatch raises
                   :class:`DispatchTimeout`: the watchdog clears the
                   compiled-runner cache and retries once
  ``evict``        the serving scheduler preempts the matching live
                   sequence mid-decode: pages freed, translation-cache
                   versions bumped, request re-queued for re-prefill

Each fault names its site, an occurrence set (``at``) counted per
(site, match) pair, and an optional substring ``match`` on the site tag
(a cache path, a bucket label, a sequence id) — so a plan like "corrupt
the second costmodel read" replays identically every run.  Install a
plan process-wide with :func:`inject_faults` (a context manager) — the
instrumented sites consult :func:`fault_injector` and fire at most the
planned occurrences.

Watchdog
--------
:func:`watchdog_call` bounds one dispatch: the callable runs on a
worker thread and a join timeout turns a hung dispatch into
:class:`DispatchTimeout`; one retry runs after the caller's
``on_timeout`` hook (the sweep engine clears the compiled-runner cache
there, the recovery a wedged XLA executable actually needs).  A
``timeout_s`` of 0 skips the thread entirely — injected
``DispatchTimeout`` still retries, so chaos plans exercise the exact
recovery path without real hangs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import tempfile
import threading
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

#: sidecar suffix holding the hex sha256 of the entry's bytes
SIDECAR_SUFFIX = ".sha256"
#: subdirectory (of the entry's cache dir) corrupted entries move to
QUARANTINE_DIR = "quarantine"


class DispatchTimeout(RuntimeError):
    """A watchdogged dispatch exceeded its deadline (or a fault plan
    injected one)."""


# ---------------------------------------------------------------------------
# recovery event log
# ---------------------------------------------------------------------------
_EVENTS: "deque[Tuple[str, str]]" = deque(maxlen=512)
_EVENTS_LOCK = threading.Lock()


def log_event(kind: str, detail: str) -> None:
    """Record one recovery decision (quarantine / cache_off / retry /
    resume / evict / shed / ...) in the bounded process-wide log."""
    with _EVENTS_LOCK:
        _EVENTS.append((kind, detail))


def recovery_events(clear: bool = False) -> List[Tuple[str, str]]:
    """The recovery decisions taken so far, oldest first."""
    with _EVENTS_LOCK:
        out = list(_EVENTS)
        if clear:
            _EVENTS.clear()
    return out


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault: fire at the given per-(site, match)
    occurrence indices of ``site`` whose tag contains ``match``."""

    site: str                    # cache_read|cache_write|dispatch|evict
    at: Tuple[int, ...] = (0,)
    match: str = ""

    def __post_init__(self):
        if self.site not in ("cache_read", "cache_write", "dispatch",
                             "evict"):
            raise ValueError(f"unknown fault site {self.site!r}")


class FaultInjector:
    """Deterministic fault plan replay (see module docstring).

    ``seed`` only matters for plans built with :meth:`from_plan` that
    draw occurrence indices; explicit :class:`Fault` lists replay
    as-is.  The injector counts occurrences per (site, match) pair, so
    a plan is insensitive to unrelated traffic on the same site with
    different tags.
    """

    def __init__(self, faults: Iterable[Fault] = (), seed: int = 0):
        self.faults = tuple(faults)
        self.seed = seed
        self._counts: Dict[Tuple[str, str], int] = {}
        self.fired: List[Tuple[str, str, int]] = []   # (site, tag, idx)

    def fires(self, site: str, tag: str = "") -> bool:
        """Advance the matching occurrence counters; True iff any
        planned fault fires at this occurrence."""
        hit = False
        for f in self.faults:
            if f.site != site or f.match not in tag:
                continue
            key = (site, f.match)
            idx = self._counts.get(key, 0)
            self._counts[key] = idx + 1
            if idx in f.at:
                hit = True
                self.fired.append((site, tag, idx))
                log_event("fault_injected", f"{site}[{idx}] {tag}")
        return hit

    @classmethod
    def from_plan(cls, name: str, seed: int = 0) -> "FaultInjector":
        """A named fault plan (the chaos-test matrix; see
        ``scripts/chaos.py``)."""
        plans: Dict[str, Tuple[Fault, ...]] = {
            # corrupt every cache family once: trace npz, costmodel
            # memo, search eval cache — plus one failed write
            "cache_corrupt": (
                Fault("cache_read", at=(0,)),
                Fault("cache_write", at=(0,)),
            ),
            # first dispatch of a bucket hangs; watchdog clears the
            # runner cache and the retry completes
            "dispatch_hang": (Fault("dispatch", at=(0,)),),
            # repeated mid-decode evictions: preempt -> re-prefill
            "evict_storm": (Fault("evict", at=(0, 1, 2)),),
        }
        if name not in plans:
            raise KeyError(f"unknown fault plan {name!r}; "
                           f"available: {sorted(plans)}")
        return cls(plans[name], seed=seed)


_INJECTOR: Optional[FaultInjector] = None


def fault_injector() -> Optional[FaultInjector]:
    """The installed process-wide injector, or None (the common case —
    every instrumented site is a dict lookup away from free)."""
    return _INJECTOR


class inject_faults:
    """Context manager installing ``injector`` process-wide."""

    def __init__(self, injector: FaultInjector):
        self.injector = injector

    def __enter__(self) -> FaultInjector:
        global _INJECTOR
        self._prev = _INJECTOR
        _INJECTOR = self.injector
        return self.injector

    def __exit__(self, *exc) -> None:
        global _INJECTOR
        _INJECTOR = self._prev


# ---------------------------------------------------------------------------
# integrity-checked cache entries
# ---------------------------------------------------------------------------
def _sidecar(path: str) -> str:
    return path + SIDECAR_SUFFIX


def quarantine(path: str, reason: str) -> Optional[str]:
    """Move a corrupted cache entry (and its sidecar) into the
    ``quarantine/`` subdirectory of its cache dir; returns the new
    path (None if the move itself failed — the entry is then unlinked
    so it cannot poison the next run either)."""
    qdir = os.path.join(os.path.dirname(path), QUARANTINE_DIR)
    dest = os.path.join(qdir, os.path.basename(path))
    try:
        os.makedirs(qdir, exist_ok=True)
        n = 0
        while os.path.exists(dest):
            n += 1
            dest = os.path.join(
                qdir, f"{os.path.basename(path)}.{n}")
        os.replace(path, dest)
        for extra in (_sidecar(path),):
            if os.path.exists(extra):
                os.replace(extra, dest + SIDECAR_SUFFIX)
        log_event("quarantine", f"{path} -> {dest} ({reason})")
        return dest
    except OSError:
        for p in (path, _sidecar(path)):
            try:
                os.unlink(p)
            except OSError:
                pass
        log_event("quarantine", f"{path} unlinked ({reason}; "
                                "quarantine dir unwritable)")
        return None


def write_bytes(path: str, data: bytes) -> bool:
    """Atomically publish ``data`` at ``path`` with its sha256 sidecar.

    Any filesystem failure — or an injected ``cache_write`` fault —
    degrades to cache-off (returns False); the caller keeps its
    computed value and simply doesn't memoize it.
    """
    tmp = None
    try:
        inj = fault_injector()
        if inj is not None and inj.fires("cache_write", path):
            raise OSError(f"injected cache_write fault: {path}")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        # sidecar first: a crash between the two renames leaves a
        # sidecar without an entry (harmless), never an unverifiable
        # entry
        digest = hashlib.sha256(data).hexdigest()
        fd2, tmp2 = tempfile.mkstemp(dir=os.path.dirname(path),
                                     suffix=".tmp")
        with os.fdopen(fd2, "w") as f:
            f.write(digest)
        os.replace(tmp2, _sidecar(path))
        os.replace(tmp, path)
        return True
    except OSError as e:
        log_event("cache_off", f"write failed: {path} ({e})")
        for p in (tmp,):
            if p is not None and os.path.exists(p):
                try:
                    os.unlink(p)
                except OSError:
                    pass
        return False


def read_bytes(path: str) -> Optional[bytes]:
    """Verified read of one cache entry; None means "recompute".

    Missing entry -> None.  Sidecar digest mismatch, unreadable file,
    or an injected ``cache_read`` fault -> the entry is quarantined
    and None is returned; the caller recomputes instead of crashing.
    """
    if not os.path.exists(path):
        return None
    inj = fault_injector()
    if inj is not None and inj.fires("cache_read", path):
        quarantine(path, "injected cache_read fault")
        return None
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError as e:
        quarantine(path, f"unreadable: {e}")
        return None
    sc = _sidecar(path)
    if os.path.exists(sc):
        try:
            with open(sc) as f:
                want = f.read().strip()
        except OSError:
            want = ""
        if want and hashlib.sha256(data).hexdigest() != want:
            quarantine(path, "sha256 sidecar mismatch")
            return None
    return data


def read_npz(path: str) -> Optional[Dict[str, np.ndarray]]:
    """Verified npz read -> array dict; corrupt entries (bit flips,
    truncation — with or without a sidecar) are quarantined and None
    is returned for transparent recompute."""
    data = read_bytes(path)
    if data is None:
        return None
    try:
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            return {k: z[k] for k in z.files}
    except Exception as e:               # zipfile/zlib/ValueError zoo
        quarantine(path, f"npz parse failed: {type(e).__name__}: {e}")
        return None


def write_npz(path: str, arrays: Dict) -> bool:
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return write_bytes(path, buf.getvalue())


def read_json(path: str):
    """Verified json read; corrupt entries quarantined, None returned."""
    data = read_bytes(path)
    if data is None:
        return None
    try:
        return json.loads(data.decode("utf-8"))
    except Exception as e:
        quarantine(path, f"json parse failed: {type(e).__name__}: {e}")
        return None


def write_json(path: str, obj, **dump_kw) -> bool:
    return write_bytes(path,
                       json.dumps(obj, **dump_kw).encode("utf-8"))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------
def watchdog_call(fn: Callable[[], object], timeout_s: float, *,
                  tag: str = "", retries: int = 1,
                  on_timeout: Optional[Callable[[], None]] = None):
    """Run ``fn`` under a wall-clock deadline with bounded retries.

    ``timeout_s > 0``: ``fn`` runs on a daemon worker thread; if it
    has not finished after ``timeout_s`` seconds the attempt counts as
    :class:`DispatchTimeout` (the hung thread is abandoned — a wedged
    XLA dispatch cannot be cancelled, only routed around).
    ``timeout_s <= 0``: ``fn`` runs inline — only *injected*
    ``DispatchTimeout`` can fire, which is how chaos plans exercise
    the retry path deterministically without real hangs.

    On timeout, ``on_timeout()`` runs before the retry (the sweep
    engine clears the compiled-runner cache there).  The last attempt's
    timeout propagates.
    """
    last: Optional[DispatchTimeout] = None
    for attempt in range(retries + 1):
        try:
            if timeout_s and timeout_s > 0:
                result: list = []
                error: list = []

                def _run():
                    try:
                        result.append(fn())
                    except BaseException as e:   # noqa: BLE001
                        error.append(e)

                t = threading.Thread(target=_run, daemon=True,
                                     name=f"watchdog:{tag}")
                t.start()
                t.join(timeout_s)
                if t.is_alive():
                    raise DispatchTimeout(
                        f"{tag or 'dispatch'} exceeded {timeout_s}s "
                        f"(attempt {attempt + 1})")
                if error:
                    raise error[0]
                return result[0]
            return fn()
        except DispatchTimeout as e:
            last = e
            log_event("watchdog_timeout",
                      f"{tag} attempt {attempt + 1}: {e}")
            if attempt >= retries:
                raise
            if on_timeout is not None:
                on_timeout()
            log_event("watchdog_retry", f"{tag} retrying "
                                        f"(attempt {attempt + 2})")
    raise last if last else RuntimeError("unreachable")
