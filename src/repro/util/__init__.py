"""Cross-cutting runtime utilities (resilience layer)."""
from repro.util.resilience import (DispatchTimeout, Fault,  # noqa: F401
                                   FaultInjector, fault_injector,
                                   inject_faults, log_event,
                                   recovery_events, watchdog_call)
