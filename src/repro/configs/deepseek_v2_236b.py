"""deepseek-v2-236b [arXiv:2405.04434].

60L d_model=5120 128H (MLA kv_lora=512) vocab=102400.
MoE: 160 routed experts top-6 + 2 shared experts, expert_d_ff=1536.
Layer 0 uses a dense FFN (d_ff=12288), layers 1..59 use MoE (per the paper).
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128.
"""
from repro.config import (ATTN_MLA, DENSE_FF, MOE_FF, ArchConfig, MLAConfig,
                          MoEConfig, register)

# layer 0 dense FFN (prefix, unscanned); layers 1..59 MoE (scanned)
_PREFIX = ((ATTN_MLA, DENSE_FF),)
_PATTERN = ((ATTN_MLA, MOE_FF),)

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,       # MLA: per-head keys reconstructed from latent
    head_dim=128,           # v_head_dim; qk dims live in MLAConfig
    d_ff=12_288,            # dense FFN (layer 0)
    vocab_size=102_400,
    layer_pattern=_PATTERN,
    prefix_pattern=_PREFIX,
    moe=MoEConfig(num_experts=160, num_experts_per_tok=6,
                  num_shared_experts=2, expert_d_ff=1536),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
))
