"""The paper's own configuration: Table I machine configs + Table II workloads.

This is not an LM architecture; it parameterizes the NDPage reproduction
simulator (repro.sim).  All latencies are in core cycles at 2.6 GHz, matching
Table I of the paper.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Tuple


@dataclass(frozen=True)
class CacheParams:
    size_bytes: int
    ways: int
    latency: int                # cycles
    line_bytes: int = 64

    @property
    def num_sets(self) -> int:
        return self.size_bytes // (self.ways * self.line_bytes)


@dataclass(frozen=True)
class TLBParams:
    entries: int
    ways: int
    latency: int


@dataclass(frozen=True)
class MachineConfig:
    """One simulated machine (CPU or NDP), per Table I."""

    name: str
    is_ndp: bool
    num_cores: int
    freq_ghz: float = 2.6
    # cache hierarchy: NDP has ONLY L1; CPU has L1+L2+L3.
    l1d: CacheParams = field(default_factory=lambda: CacheParams(32 * 1024, 8, 4))
    l2: CacheParams | None = None
    l3: CacheParams | None = None
    # MMU
    l1_dtlb: TLBParams = field(default_factory=lambda: TLBParams(64, 4, 1))
    l2_tlb: TLBParams = field(default_factory=lambda: TLBParams(1536, 12, 12))
    # page-walk caches: one per upper level, near-ideal for L4/L3 (paper VI)
    pwc_entries: int = 32
    pwc_latency: int = 2
    # memory system: a declarative repro.sim.memory_model.MemoryModel —
    # DDR4-2400 (CPU) vs HBM2 (NDP) latencies in core cycles; HBM2 row
    # access is slightly slower than DDR4 but the NDP core sits in the
    # logic layer -> much lower interconnect cost and higher bandwidth.
    # Accepts a MemoryModel, a preset name ("bounded_linear"/"banked"),
    # a field dict, or None (the bounded_linear DDR4 default).  The old
    # flat kwargs mem_latency/mem_bandwidth_gbs/mem_service still work
    # (deprecated, one warning per process) and read back as properties.
    memory: Any = None
    interconnect_hop: int = 4       # mesh hop latency, cycles
    interconnect_hops_to_mem: int = 8
    # --- mechanism-zoo knobs (all inert at their defaults) ---
    # cache-as-TLB (Victima): ctlb_kb KB of cache capacity repurposed as
    # a second large TLB level, one translation per repurposed 64B line.
    # 0 = the structure does not exist (compiled shapes unchanged);
    # raising it is the occupancy/demotion knob — more lines demoted to
    # translation duty, more reach.
    ctlb_kb: int = 0
    ctlb_ways: int = 8
    ctlb_latency: int = 16          # L2-cache-latency-class probe
    # multi-stack NDP memory (CODA): with >1 stacks a fraction
    # (1 - 1/num_stacks) of memory accesses land in a REMOTE stack and
    # pay stack_hop_cycles extra; co-location-aware mechanisms dodge
    # most of it.  num_stacks=1 => no penalty anywhere.
    num_stacks: int = 1
    stack_hop_cycles: int = 36

    def __post_init__(self):
        # lazy import: repro.sim.memory_model lives under the repro.sim
        # package whose __init__ imports modules that import THIS module
        # — resolving at first-instantiation time (module fully loaded)
        # keeps either import order working
        from repro.sim.memory_model import resolve_memory_model
        object.__setattr__(self, "memory", resolve_memory_model(self.memory))

    # -- deprecated flat memory fields, kept readable as views ------------
    @property
    def mem_latency(self) -> float:
        """Deprecated: read ``memory.latency``."""
        return self.memory.latency

    @property
    def mem_bandwidth_gbs(self) -> float:
        """Deprecated: read ``memory.bandwidth_gbs``."""
        return self.memory.bandwidth_gbs

    @property
    def mem_service(self) -> float:
        """Deprecated: read ``memory.service``."""
        return self.memory.service


# Legacy-kwarg shim: MachineConfig(mem_latency=..., mem_service=...,
# mem_bandwidth_gbs=...) — including via dataclasses.replace() — folds
# the flat values into ``memory`` with ONE DeprecationWarning per
# process (the PR-9 idiom).  A wrapped __init__ rather than InitVar
# fields so the deprecated names never reappear as real fields (asdict,
# repr, and the sweep checkpoint keys stay clean).
_dc_init = MachineConfig.__init__


@functools.wraps(_dc_init)
def _init_with_legacy_mem(self, *args, **kwargs):
    from repro.sim.memory_model import LEGACY_FIELDS, warn_legacy_memory
    legacy = {LEGACY_FIELDS[k]: kwargs.pop(k)
              for k in tuple(kwargs) if k in LEGACY_FIELDS}
    _dc_init(self, *args, **kwargs)
    if legacy:
        warn_legacy_memory("MachineConfig(" +
                           "/".join(f"{k}=" for k in LEGACY_FIELDS) + ")")
        object.__setattr__(self, "memory", replace(self.memory, **legacy))


MachineConfig.__init__ = _init_with_legacy_mem


def cpu_machine(cores: int) -> MachineConfig:
    return MachineConfig(
        name=f"cpu-{cores}c", is_ndp=False, num_cores=cores,
        l2=CacheParams(512 * 1024, 16, 16),
        # Table I: 2MB/core — modelled as a private 2MB slice per core
        l3=CacheParams(2 * 1024 * 1024, 16, 35),
        memory=dict(latency=170.0,          # DDR4 ~65ns @2.6GHz
                    bandwidth_gbs=19.2, service=12.0),
        interconnect_hops_to_mem=8,
    )


def ndp_machine(cores: int) -> MachineConfig:
    return MachineConfig(
        name=f"ndp-{cores}c", is_ndp=True, num_cores=cores,
        l2=None, l3=None,
        # NDP core in the logic layer: short path to the stacked DRAM.
        # HBM2 4-stack; irregular single-line accesses are row-miss/
        # bank-limited, not peak-BW-limited: the bounded service is
        # tRC(~45ns=117cyc)/active-banks + ctrl overhead — the banked
        # preset models the same budget structurally (117cyc per bank).
        memory=dict(latency=100.0, bandwidth_gbs=307.2, service=46.0),
        interconnect_hops_to_mem=1,
    )


def zoo_machine(cores: int) -> MachineConfig:
    """The mechanism-zoo comparison point: an NDP machine with the
    related-work structures enabled — 256KB of cache repurposable as
    translation reach (Victima) and a 4-stack memory with a
    local-vs-remote latency split (CODA).  Mechanisms that do not use a
    structure simply ignore it, so the paper's five behave exactly as on
    ``ndp_machine`` apart from the multi-stack memory penalty every
    non-co-locating design pays."""
    base = ndp_machine(cores)
    from dataclasses import replace
    return replace(base, name=f"zoo-{cores}c", ctlb_kb=256,
                   num_stacks=4)


# Table II — workload trace parameters.  footprint_bytes reproduces the
# dataset sizes; pattern keys map to generators in repro.workloads.
WORKLOADS: Dict[str, dict] = {
    "bc":   dict(suite="GraphBIG", pattern="graph", footprint_gb=8,  alpha=2.1),
    "bfs":  dict(suite="GraphBIG", pattern="graph_frontier", footprint_gb=8, alpha=2.1),
    "cc":   dict(suite="GraphBIG", pattern="graph", footprint_gb=8,  alpha=2.3),
    "gc":   dict(suite="GraphBIG", pattern="graph", footprint_gb=8,  alpha=2.2),
    "pr":   dict(suite="GraphBIG", pattern="graph_sweep", footprint_gb=8, alpha=2.1),
    "tc":   dict(suite="GraphBIG", pattern="graph", footprint_gb=8,  alpha=1.9),
    "sp":   dict(suite="GraphBIG", pattern="graph_frontier", footprint_gb=8, alpha=2.0),
    "xs":   dict(suite="XSBench",  pattern="mc_lookup", footprint_gb=9),
    "rnd":  dict(suite="GUPS",     pattern="uniform", footprint_gb=10),
    "dlrm": dict(suite="DLRM",     pattern="embedding_bag", footprint_gb=10),
    "gen":  dict(suite="GenomicsBench", pattern="kmer", footprint_gb=33),
}

CORE_COUNTS: Tuple[int, ...] = (1, 4, 8)


# ---------------------------------------------------------------------------
# simulation presets
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SimPreset:
    """A (trace window, footprint scale, seed, chunk) bundle.

    ``smoke`` shrinks the simulated window so the full simulator code
    path runs at CI cost.  The footprint deliberately stays at Table-II
    scale: footprints are synthetic numbers (no memory/compute cost) and
    the paper's effects require footprint >> TLB reach and a PT working
    set that overflows PWC+L1 — shrinking it collapses exactly the
    ratios the ordering tests assert.  ``footprint_scale`` exists as a
    knob for experiments that want it.  ``full`` is the paper-figure
    configuration.
    """

    name: str
    trace_len: int
    footprint_scale: float      # multiplies Table-II footprint_gb
    seed: int
    chunk: int                  # scan chunk length (see repro.sim.simulator)


PRESETS: Dict[str, SimPreset] = {
    "smoke": SimPreset("smoke", trace_len=2048, footprint_scale=1.0,
                       seed=1234, chunk=512),
    "full": SimPreset("full", trace_len=8000, footprint_scale=1.0,
                      seed=0, chunk=1024),
}


# ---------------------------------------------------------------------------
# sensitivity-sweep presets (consumed by repro.sim.sweep(name))
# ---------------------------------------------------------------------------
#: the workload subset the sensitivity figures sweep over: one per
#: suite-level behaviour (uniform, graph, frontier, MC lookup,
#: embedding, k-mer) — 6 workloads x 4 machine variants = 24 points
SWEEP_WORKLOADS: Tuple[str, ...] = ("rnd", "bc", "bfs", "xs", "dlrm",
                                    "gen")

#: Declarative grids for the paper's sensitivity studies.  Each entry is
#: plain data: ``axes`` is an ordered (name, values) tuple — special
#: names workload/machine/cores/mechs, everything else a MachineConfig
#: override path — plus optional base/cores/workload/mechs/preset
#: defaults and a human-facing ``figure`` note.  Shape-changing axes
#: (PWC/TLB sizes) cost one compile per size; value-only axes
#: (latencies, bypass flags) share ONE compiled runner across the whole
#: grid — the bucketing is asserted in tests/test_sweep.py.
SWEEPS: Dict[str, dict] = {
    # PWC sizing: NDPage keeps its lead at every page-walk-cache size
    "pwc_size": dict(
        axes=(("pwc_entries", (8, 16, 32, 64)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="PWC-size sensitivity (4 shapes, 24 points)"),
    # L1-DTLB sizing: translation overhead vs TLB reach
    "tlb_size": dict(
        axes=(("l1_dtlb.entries", (32, 64, 128, 256)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="L1-DTLB-size sensitivity (4 shapes, 24 points)"),
    # L1-bypass ablation: ndpage vs ndpage_nobyp share walk functions,
    # so BOTH mechanism tuples land in one shape bucket (bypass is
    # per-lane data) — 24 points, at most one compile
    "l1_bypass": dict(
        axes=(("mechs", (("radix", "ndpage", "ideal"),
                         ("radix", "ndpage_nobyp", "ideal"))),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="L1-bypass on/off ablation (1 shape, 12 points)"),
    # flattened-level choice: PL2-merge (ndpage) vs PL3-merge
    # (ndpage_pl3) — different walk functions, two buckets
    "flatten_level": dict(
        axes=(("mechs", (("radix", "ndpage", "ideal"),
                         ("radix", "ndpage_pl3", "ideal"))),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="flattened-level choice PL2 vs PL3 (2 buckets)"),
    # core scaling: the paper's 1/4/8-core study as one sweep
    "core_scaling": dict(
        axes=(("cores", CORE_COUNTS),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp",
        figure="1/4/8-core scaling (3 shapes, 18 points)"),
    # memory latency: pure value axis — 24 points, ONE compiled runner
    "mem_latency": dict(
        axes=(("memory.latency", (60.0, 100.0, 170.0, 240.0)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="memory-latency sensitivity (1 shape, 24 points, "
               "1 compile)"),
    # banked DRAM timing: switch the memory model to the banked preset
    # (ONE shape — bank geometry is compiled in), then sweep the
    # open/closed-row timings as pure value axes.  memory_model comes
    # FIRST: overrides apply in axis order, so t_cas/t_rp land on the
    # already-banked model.
    "banked_timing": dict(
        axes=(("memory_model", ("banked",)),
              ("memory.t_cas", (15.0, 25.0, 40.0)),
              ("memory.t_rp", (20.0, 30.0)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        figure="banked DRAM timing sensitivity (1 shape, 36 points, "
               "1 compile)"),
    # mechanism zoo: the related-work designs (Victima cache-as-TLB,
    # Picorel inverted/segment, CODA co-location, range table) against
    # the paper set on the zoo machine (ctlb enabled, 4 memory stacks).
    # One mechs tuple + one shape => ONE bucket for all 6 points.
    "zoo": dict(
        axes=(("ctlb_kb", (256,)),
              ("num_stacks", (4,)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        mechs=("radix", "ndpage_search", "victima", "picorel",
               "coda", "range_table", "ideal"),
        figure="related-work mechanism zoo (1 shape, 6 points, "
               "1 compile)"),
    # Victima reach: sweep the cache-capacity-repurposing (demotion /
    # promotion occupancy) knob — each ctlb_kb is a distinct shape
    "victima_reach": dict(
        axes=(("ctlb_kb", (64, 128, 256, 512)),
              ("workload", SWEEP_WORKLOADS)),
        base="ndp", cores=4,
        mechs=("radix", "victima", "ideal"),
        figure="Victima cache-as-TLB reach sensitivity "
               "(4 shapes, 24 points)"),
}


# ---------------------------------------------------------------------------
# design-space-search presets (consumed by repro.sim.search(name))
# ---------------------------------------------------------------------------
#: the two committed real-format fixture traces, as "trace:" workload
#: specs (paths relative to the repo root; the search layer absolutizes
#: them) — the search objective averages over the figure-suite workload
#: subset PLUS these, so a config that only wins on synthetics can't
#: climb the frontier
SEARCH_FIXTURES: Tuple[str, ...] = (
    "trace:tests/fixtures/traces/gups_small.champsim.xz",
    "trace:tests/fixtures/traces/graph_small.lackey.gz",
)

#: Declarative design spaces for the automated search.  Each entry is
#: plain data consumed by ``repro.sim._search``: ``knobs`` is an ordered
#: (name, values) tuple — ``flatten``/``l1_bypass``/``huge`` select the
#: candidate's mechanism STRUCTURE from the registry family,
#: ``l1_dtlb`` is an (entries, ways) geometry bundle, everything else a
#: MachineConfig override path — plus the population sizing, the
#: workload suite the fitness averages over, and the pinned seed that
#: makes CI runs hermetic.  Geometry knobs change compiled shapes (one
#: compile per distinct shape x flatten level, amortized by the runner
#: cache and ``.jax_cache``); flag knobs ride the batch lanes as data.
SEARCH_SPACES: Dict[str, dict] = {
    # the standard seeded search: 4x3x2 machine geometries x 2 PWC
    # latencies x 8 mechanism structures = 384 genomes; >= 200
    # evaluated across <= 10 generations (1 paper + 56 random +
    # 6 x 24 offspring = 201).  pwc_latency is a VALUE-ONLY knob —
    # it rides the batch lanes and adds no compile buckets
    "default": dict(
        knobs=(("pwc_entries", (8, 16, 32, 64)),
               ("pwc_latency", (2, 4)),
               ("l1_dtlb", ((64, 4), (128, 8), (256, 8))),
               ("l2_tlb.entries", (1536, 3072)),
               ("flatten", ("pl2", "pl3")),
               ("l1_bypass", (True, False)),
               ("huge", (False, True))),
        cores=4,
        workloads=SWEEP_WORKLOADS + SEARCH_FIXTURES,
        n_random=56, population=32, generations=6, offspring=24,
        trace_len=512, chunk=512, preset="smoke", seed=20250808),
    # mechanism zoo as a genome knob: which related-work design to run
    # is itself searched, alongside the structures they need (ctlb
    # reach for victima, a fixed 4-stack memory so co-location
    # matters).  ``zoo_mech`` overrides the structural triple; paper
    # default is ``ndpage`` (see search.PAPER_DEFAULTS).
    "zoo": dict(
        knobs=(("pwc_entries", (16, 32)),
               ("ctlb_kb", (0, 256)),
               ("num_stacks", (4,)),
               ("zoo_mech", ("ndpage_search", "victima", "picorel",
                             "coda", "range_table"))),
        cores=4,
        workloads=("rnd", "bc", "xs") + SEARCH_FIXTURES,
        n_random=12, population=8, generations=1, offspring=6,
        trace_len=512, chunk=512, preset="smoke", seed=11),
    # memory-model space: is the banked row-buffer model worth its
    # compile bucket, and does it move the structural knobs' frontier?
    # ``memory_model`` is a genome knob applied via apply_param (the
    # banked kind keys its own shape bucket; a NEW space rather than a
    # "default" extension so the committed frontier baseline's genome
    # schema stays untouched).
    "memory": dict(
        knobs=(("pwc_entries", (16, 32)),
               ("flatten", ("pl2", "pl3")),
               ("l1_bypass", (True, False)),
               ("memory_model", ("bounded_linear", "banked"))),
        cores=4,
        workloads=("rnd", "bc", "xs") + SEARCH_FIXTURES[:1],
        n_random=12, population=8, generations=1, offspring=8,
        trace_len=512, chunk=512, preset="smoke", seed=29),
    # PR fast lane: 1 generation over a 2-shape slice, sub-minute even
    # with cold compile caches
    "quick": dict(
        knobs=(("pwc_entries", (16, 32)),
               ("flatten", ("pl2", "pl3")),
               ("l1_bypass", (True, False)),
               ("huge", (False, True))),
        cores=4,
        workloads=("rnd", "bc", "xs") + SEARCH_FIXTURES[:1],
        n_random=10, population=8, generations=1, offspring=6,
        trace_len=512, chunk=512, preset="smoke", seed=7),
}


# ---------------------------------------------------------------------------
# translation-costed serving preset (consumed by repro.sim.cost_model and
# benchmarks/serving_translation.py)
# ---------------------------------------------------------------------------
#: The machine/workload point the serving cost table is derived from,
#: plus the serving model's compute budget.  Plain data, like SWEEPS.
#:
#: * machine/cores — the serving machine (NDP logic-layer cores run the
#:   paged-KV engine in this scenario; 4 cores = the paper's midpoint)
#: * workload — the trace whose access structure prices the walks:
#:   dlrm (embedding-bag bursts) is the closest Table-II analogue of
#:   paged-KV gathers
#: * mechs — mechanism order every serving report follows
#: * model_cycles_per_token — non-translation compute per decoded token
#:   on the serving cores; sized so translation is a visible-but-minor
#:   fraction (the paper's regime: tens of percent at the extremes)
SERVING_COST: Dict[str, object] = dict(
    machine="ndp", cores=4,
    workload="dlrm",
    mechs=("radix", "ech", "hugepage", "ndpage", "ideal"),
    preset="smoke",
    model_cycles_per_token=1500.0,
)

#: the fleet-scale serving benchmark (benchmarks/serving_fleet.py):
#: request-mix shape, translation-budget run, and the
#: model-cycles-per-token grid the accumulated translation cycles are
#: re-priced under (mapping where translation stops mattering).  The
#: smoke variant trims counts, never structure.
SERVING_FLEET: Dict[str, object] = dict(
    max_batch=1024, max_len=64, page_size=8, leaf_size=4,
    num_requests=1536,
    prefix_groups=32, prefix_len=32,      # 32 tokens = 4 full pages
    tail_tokens=8, new_tokens=16,
    independent_prompt=(24, 40),          # the no-prefix control mix
    translation_budget=6_000.0,           # cycles/step, budget run
    budget_mech="ndpage",
    mcpt_grid=(150.0, 500.0, 1500.0, 5000.0, 15000.0),
    smoke=dict(max_batch=256, num_requests=384, prefix_groups=8),
)


def __getattr__(name: str):
    # MECHANISMS is sourced from the one spec registry (repro.sim.mechanisms)
    # but resolved lazily: the simulator imports this module for
    # MachineConfig, so an eager import here would be circular.
    if name == "MECHANISMS":
        from repro.sim.mechanisms import DEFAULT_MECHS
        return DEFAULT_MECHS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
