"""granite-34b [arXiv:2405.04324] — Granite Code 34B.

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152, llama-style arch.
MQA (single kv head) makes the metadata:data ratio of the paged-KV path the
highest of the assigned pool (see DESIGN.md §4).
"""
from repro.config import ATTN, DENSE_FF, ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    layer_pattern=((ATTN, DENSE_FF),),
    gated_ffn=False,   # granite-code 34b uses GPT-style MLP (gelu)
))
