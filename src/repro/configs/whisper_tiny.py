"""whisper-tiny [arXiv:2212.04356].

Enc-dec: 4 encoder + 4 decoder layers, d_model=384 6H d_ff=1536 vocab=51865.
The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (1500 frames of d_model) and the
encoder consumes them directly.
"""
from repro.config import ATTN, DENSE_FF, ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    encoder_seq_len=1500,    # 30 s of audio at 50 Hz after the conv stub
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    layer_pattern=((ATTN, DENSE_FF),),
    gated_ffn=False,         # whisper uses GELU MLP
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions; we
                             # use sinusoidal added at embed time (no rope)
    tie_embeddings=True,
))
