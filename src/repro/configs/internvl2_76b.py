"""internvl2-76b [arXiv:2404.16821] — InternViT-6B + Llama3-70B-style LM.

Assigned backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The InternViT vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (256 visual tokens of d_model) which
are prepended to the text embedding sequence.
"""
from repro.config import ATTN, DENSE_FF, ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    layer_pattern=((ATTN, DENSE_FF),),
    vision_tokens=256,
    rope_theta=500_000.0,
))
