"""gemma3-1b [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256.
5:1 local:global attention interleave, sliding window 512, 128k context
(32k for the 1b variant upstream; we honor the assigned shape suite).
"""
from repro.config import ATTN, ATTN_LOCAL, DENSE_FF, ArchConfig, register

# one period = 5 sliding-window layers then 1 global layer.
# 26 layers = 2 unscanned local layers (prefix) + 4 periods of 6.
_PREFIX = ((ATTN_LOCAL, DENSE_FF),) * 2
_PATTERN = ((ATTN_LOCAL, DENSE_FF),) * 5 + ((ATTN, DENSE_FF),)

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=_PATTERN,
    prefix_pattern=_PREFIX,
    window_size=512,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))
