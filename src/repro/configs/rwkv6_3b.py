"""rwkv6-3b (Finch) [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Data-dependent decay; head_size=64 -> 40 heads; per-head matrix state
(64x64) replaces the KV cache entirely — the paper's paged-translation
technique is inapplicable to this arch's memory path (DESIGN.md §4).
"""
from repro.config import DENSE_FF, RWKV, ArchConfig, RWKVConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65_536,
    layer_pattern=((RWKV, DENSE_FF),),
    rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=32),
    gated_ffn=False,   # rwkv channel-mix is relu^2 MLP (2-matrix)
))
