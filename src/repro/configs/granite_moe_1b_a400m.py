"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) expert_d_ff=512 vocab=49155, MoE 32e top-8.
Every layer uses a routed MoE FFN (granite-3.0 MoE family).
"""
from repro.config import ATTN, MOE_FF, ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    layer_pattern=((ATTN, MOE_FF),),
    moe=MoEConfig(num_experts=32, num_experts_per_tok=8, expert_d_ff=512),
    tie_embeddings=True,
    gated_ffn=True,
))
