"""Architecture config registry — one module per assigned architecture.

Importing this package registers all architectures with repro.config.
"""
from repro.configs import (  # noqa: F401
    granite_moe_1b_a400m,
    deepseek_v2_236b,
    gemma3_1b,
    granite_34b,
    internlm2_1_8b,
    phi3_medium_14b,
    whisper_tiny,
    jamba_1_5_large_398b,
    internvl2_76b,
    rwkv6_3b,
    ndp_sim,
)
