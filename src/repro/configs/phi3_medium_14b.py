"""phi3-medium-14b [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE + SwiGLU.
"""
from repro.config import ATTN, DENSE_FF, ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17_920,
    vocab_size=100_352,
    layer_pattern=((ATTN, DENSE_FF),),
))
