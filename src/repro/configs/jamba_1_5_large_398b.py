"""jamba-1.5-large-398b [arXiv:2403.19887 / Jamba-1.5 report].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Hybrid Mamba : attention at 1:7 (one attention layer per 8), MoE 16 experts
top-2 on every other layer.  Period of 8 layers:
  [mamba+ff, mamba+moe, mamba+ff, attn+moe, mamba+ff, mamba+moe, mamba+ff, mamba+moe]
(attention at in-period index 3, MoE on odd indices — matches the published
1:7 attention ratio and every-2-layers MoE placement).
"""
from repro.config import (ATTN, DENSE_FF, MAMBA, MOE_FF, ArchConfig,
                          MambaConfig, MoEConfig, register)

_PATTERN = (
    (MAMBA, DENSE_FF),
    (MAMBA, MOE_FF),
    (MAMBA, DENSE_FF),
    (ATTN, MOE_FF),
    (MAMBA, DENSE_FF),
    (MAMBA, MOE_FF),
    (MAMBA, DENSE_FF),
    (MAMBA, MOE_FF),
)

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    layer_pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, num_experts_per_tok=2, expert_d_ff=24_576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
))
