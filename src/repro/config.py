"""Config system for the repro framework.

Every architecture is described by an :class:`ArchConfig` dataclass and
registered in ``repro.configs``.  Shapes (seq_len x global_batch cells) are
described by :class:`ShapeConfig`.  The launcher selects both via
``--arch <id> --shape <id>``.

The config system is deliberately dependency-free (no flax / ml_collections):
plain frozen dataclasses + a registry, so it is importable anywhere (including
before jax initializes devices, which the dry-run requires).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds (layer-pattern vocabulary)
# ---------------------------------------------------------------------------
ATTN = "attn"            # full softmax attention (GQA/MQA/MHA)
ATTN_LOCAL = "attn_local"  # sliding-window attention
ATTN_MLA = "attn_mla"    # DeepSeek multi-head latent attention
MAMBA = "mamba"          # selective SSM block
RWKV = "rwkv"            # RWKV6 time-mix block
DENSE_FF = "ff"          # dense (possibly gated) FFN
MOE_FF = "moe"           # routed mixture-of-experts FFN


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    num_shared_experts: int = 0
    expert_d_ff: int = 0           # intermediate size of each routed expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    gate_lora: int = 32


@dataclass(frozen=True)
class ArchConfig:
    """Architecture description. All dims are exact per the assignment."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attn-free archs)
    num_kv_heads: int
    d_ff: int                       # dense FFN intermediate size
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # layer pattern: the stack is ``prefix_pattern`` (unscanned layers) followed
    # by N periods of ``layer_pattern`` scanned with lax.scan, where
    # N = (num_layers - len(prefix_pattern)) / len(layer_pattern) must divide
    # exactly.  Homogeneous archs use a single-entry pattern and no prefix.
    layer_pattern: Tuple[Tuple[str, str], ...] = ((ATTN, DENSE_FF),)
    prefix_pattern: Tuple[Tuple[str, str], ...] = ()

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # sliding-window attention
    window_size: int = 0            # 0 -> no local attention layers

    # encoder-decoder (whisper): encoder layer count (decoder = num_layers)
    encoder_layers: int = 0
    encoder_seq_len: int = 0        # e.g. 1500 audio frames
    # vlm: number of vision-patch embeddings prepended (stub frontend)
    vision_tokens: int = 0

    # misc
    rope_theta: float = 10_000.0
    rms_norm_eps: float = 1e-5
    tie_embeddings: bool = False
    gated_ffn: bool = True          # SwiGLU-style if True, GELU MLP otherwise
    dtype: str = "bfloat16"
    # parallelism hints
    remat: bool = True              # activation checkpointing in train_step
    fsdp: bool = True               # shard params/optimizer over the data axis (ZeRO-3)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return all(m in (MAMBA, RWKV) for m, _ in self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def num_periods(self) -> int:
        n = self.num_layers - len(self.prefix_pattern)
        if n % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: {n} scanned layers not divisible by period "
                f"{len(self.layer_pattern)}")
        return n // len(self.layer_pattern)

    def layer_kinds(self) -> List[Tuple[str, str]]:
        """Expanded per-layer (mixer, ffn) kinds, length == num_layers."""
        out: List[Tuple[str, str]] = list(self.prefix_pattern)
        out.extend(list(self.layer_pattern) * self.num_periods)
        assert len(out) == self.num_layers
        return out

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        kinds = self.layer_kinds()
        d = self.d_model
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for mixer, ffn in kinds:
            total += self._mixer_params(mixer) + self._ffn_params(ffn)
            total += 2 * d  # two norms
        if self.is_encdec:
            # encoder blocks: self-attn + ffn + norms, plus cross-attn in dec
            enc = self.encoder_layers * (
                self._mixer_params(ATTN) + self._ffn_params(DENSE_FF) + 2 * d)
            cross = self.num_layers * (self._mixer_params(ATTN) + d)
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        kinds = self.layer_kinds()
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for mixer, ffn in kinds:
            total += self._mixer_params(mixer) + 2 * d
            if ffn == MOE_FF:
                assert self.moe is not None
                e_p = self._expert_params()
                total += (self.moe.num_experts_per_tok
                          + self.moe.num_shared_experts) * e_p
                total += d * self.moe.num_experts  # router
            else:
                total += self._ffn_params(ffn)
        return total

    def _expert_params(self) -> int:
        assert self.moe is not None
        dff = self.moe.expert_d_ff or self.d_ff
        mult = 3 if self.gated_ffn else 2
        return mult * self.d_model * dff

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        if kind == MOE_FF:
            assert self.moe is not None
            total = self.moe.num_experts * self._expert_params()
            total += self.moe.num_shared_experts * self._expert_params()
            total += d * self.moe.num_experts  # router
            return total
        mult = 3 if self.gated_ffn else 2
        return mult * d * self.d_ff

    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind in (ATTN, ATTN_LOCAL):
            q = d * self.num_heads * self.head_dim
            kv = 2 * d * self.num_kv_heads * self.head_dim
            o = self.num_heads * self.head_dim * d
            return q + kv + o
        if kind == ATTN_MLA:
            assert self.mla is not None
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank                      # q down
            p += m.q_lora_rank * self.num_heads * qk_dim  # q up
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down + shared k_rope
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.num_heads * m.v_head_dim * d     # out
            return p
        if kind == MAMBA:
            assert self.mamba is not None
            mc = self.mamba
            d_in = mc.expand * d
            dt_rank = mc.dt_rank or -(-d // 16)
            p = d * 2 * d_in                 # in_proj (x and z)
            p += d_in * mc.d_conv            # conv1d
            p += d_in * (dt_rank + 2 * mc.d_state)  # x_proj
            p += dt_rank * d_in              # dt_proj
            p += d_in * mc.d_state           # A_log
            p += d_in                        # D
            p += d_in * d                    # out_proj
            return p
        if kind == RWKV:
            assert self.rwkv is not None
            # r,k,v,g,o projections + decay/mix loras + ln_x
            p = 5 * d * d
            p += d * (self.rwkv.decay_lora + self.rwkv.gate_lora) * 2
            p += 6 * d  # token-shift mix params
            return p
        raise ValueError(f"unknown mixer kind {kind}")


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / O(1)-state paths).
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "jamba-1.5-large-398b", "gemma3-1b")


def cells_for(arch: "ArchConfig") -> List[str]:
    """The runnable shape cells for an architecture (skips noted in DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.name in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # configs register themselves on import
    import repro.configs  # noqa: F401


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """A reduced config of the same family for CPU smoke tests."""
    changes: Dict[str, Any] = dict(
        name=cfg.name + "-smoke",
        num_layers=len(cfg.prefix_pattern) + max(2, len(cfg.layer_pattern)) if
        len(cfg.layer_pattern) > 1 or cfg.prefix_pattern else 2,
        d_model=64,
        d_ff=128,
        vocab_size=257,
        head_dim=16 if cfg.num_heads else 0,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq_len=16 if cfg.encoder_seq_len else 0,
        vision_tokens=4 if cfg.vision_tokens else 0,
        remat=False,
        fsdp=False,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            num_experts=4, num_experts_per_tok=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            expert_d_ff=32)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=16, q_lora_rank=24,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
    if cfg.mamba is not None:
        changes["mamba"] = MambaConfig(d_state=4, d_conv=2, expand=2, dt_rank=4)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVConfig(head_size=16, decay_lora=8, gate_lora=8)
    new = dataclasses.replace(cfg, **changes)
    # not registered: smoke variants are anonymous
    return new
