"""Serving-side translation tables: logical KV page -> physical KV page.

Two organizations, mirroring the paper:

  * radix (2-level): per-sequence directory -> shared leaf tables -> physical
    page.  Lookup = TWO dependent gathers (the deep-tree baseline).
  * flat (NDPage): one per-sequence table -> physical page.  Lookup = ONE
    gather.  This is the paper's flattened L2/L1 node: decode sequences fill
    their logical pages densely (Observation B holds — occupancy ~1), so the
    directory level buys no space worth its extra indirection.

``flatten_radix`` is the NDPage merge operation; ``kv_page_manager`` decides
when to apply it from measured occupancy.

All tables are int32 device arrays; host-side allocation lives in
kv_page_manager.PagePool (allocation never happens inside jit — the
scheduler allocates between steps, exactly like the OS allocates PT nodes
outside the walk).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

FLAT = "paged_flat"
RADIX = "paged_radix"
#: zoo organizations (cost-accounting only: range/segment descriptors
#: and inverted-hash buckets don't need their own lookup structures —
#: the flat table IS the mapping; they differ in how many 64B table
#: lines a rebuild touches)
SEGMENT = "paged_segment"
INVERTED = "paged_inverted"

#: int32 table entries per 64B cache line — the granularity the costed
#: translate variants count "touched PTE lines" at
PTE_PER_LINE = 16
#: 16B (base, limit, target) range descriptors per 64B line — the
#: SEGMENT organization's packing
RANGES_PER_LINE = 4


@dataclass
class RadixTable:
    """directory: (B, n_dir) int32 leaf-table ids (-1 = unallocated)
    leaves: (n_leaf_tables, leaf_size) int32 physical page ids (-1 = hole)."""
    directory: jnp.ndarray
    leaves: jnp.ndarray

    @property
    def leaf_size(self) -> int:
        return self.leaves.shape[1]

    def tree_flatten(self):
        return (self.directory, self.leaves), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    RadixTable, RadixTable.tree_flatten, RadixTable.tree_unflatten)


def translate_all(table, mode: str) -> jnp.ndarray:
    """Full logical->physical map for every sequence: (B, max_pages) int32.

    flat:  zero extra indirections (the table IS the map).
    radix: one extra dependent gather through the directory.
    """
    if mode == FLAT:
        return table
    if mode == RADIX:
        # gather leaves for each directory entry: (B, n_dir, leaf_size)
        dir_ = jnp.maximum(table.directory, 0)
        gathered = table.leaves[dir_]
        valid = (table.directory >= 0)[..., None]
        gathered = jnp.where(valid, gathered, -1)
        b, n_dir, ls = gathered.shape
        return gathered.reshape(b, n_dir * ls)
    raise ValueError(mode)


def translate_one(table, seq_idx: jnp.ndarray, logical_page: jnp.ndarray,
                  mode: str) -> jnp.ndarray:
    """Physical page for (seq, logical_page); both (B,) arrays."""
    if mode == FLAT:
        return table[seq_idx, logical_page]
    if mode == RADIX:
        ls = table.leaf_size
        leaf_id = table.directory[seq_idx, logical_page // ls]
        return table.leaves[jnp.maximum(leaf_id, 0), logical_page % ls]
    raise ValueError(mode)


def _lines_of(mapped: jnp.ndarray) -> jnp.ndarray:
    """Touched 64B lines of a line-aligned entry span: ``mapped`` is
    (..., n) bool over consecutive int32 entries starting at a line
    boundary; returns (...,) counts of 16-entry groups with any mapped
    entry."""
    n = mapped.shape[-1]
    pad = (-n) % PTE_PER_LINE
    m = jnp.pad(mapped, [(0, 0)] * (mapped.ndim - 1) + [(0, pad)])
    groups = m.reshape(m.shape[:-1] + (-1, PTE_PER_LINE))
    return groups.any(-1).sum(-1).astype(jnp.int32)


def count_pte_lines(table, mode: str) -> jnp.ndarray:
    """The translation COST signal alone: how many distinct PTE cache
    lines (64B, :data:`PTE_PER_LINE` entries) a full row rebuild
    touches per sequence, (B,) int32.

    Line counting follows each organization's allocation story:

    * flat: the row is ONE contiguous span, so logical pages that would
      sit in different radix leaves share lines — the NDPage merge's
      locality win.
    * radix: the directory row is contiguous, but every leaf table is
      its own line-aligned allocation (the OS places each tree node on
      its own page) — leaves never share lines with each other, though
      a PREFIX-SHARED leaf referenced by several directory entries of
      one sequence is only walked (and counted) once.
    """
    if mode == FLAT:
        return _lines_of(table >= 0)
    if mode == RADIX:
        dir_ = table.directory                        # (B, n_dir)
        n_dir = dir_.shape[-1]
        dir_lines = _lines_of(dir_ >= 0)
        gathered = table.leaves[jnp.maximum(dir_, 0)]  # (B, n_dir, ls)
        valid = dir_ >= 0
        mapped = (gathered >= 0) & valid[..., None]
        # drop repeat references to a shared leaf: entry d is a dup if
        # an earlier valid entry e < d names the same leaf table
        same = (dir_[:, :, None] == dir_[:, None, :]) \
            & valid[:, :, None] & valid[:, None, :]
        j = jnp.arange(n_dir)
        dup = (same & (j[:, None] > j[None, :])).any(-1)  # (B, n_dir)
        mapped = mapped & ~dup[..., None]
        leaf_lines = _lines_of(mapped)                 # (B, n_dir)
        return dir_lines + leaf_lines.sum(-1).astype(jnp.int32)
    if mode == SEGMENT:
        return count_segment_lines(table)
    if mode == INVERTED:
        return count_inverted_lines(table)
    raise ValueError(mode)


def count_pte_lines_shared(flat: jnp.ndarray, leaf_size: int
                           ) -> jnp.ndarray:
    """RADIX-org line counts of flat rows with BATCH-GLOBAL shared-leaf
    dedup, (B,) int32: a leaf whose physical pages are identical across
    sequences (a prefix-shared system prompt) is one allocation the OS
    maps into every sharer's tree, so a step that walks several sharers
    touches its lines ONCE — charged to the first row (row-major) that
    references it.  Directory rows stay per-sequence.

    This is the radix organization's line-sharing win the flat org
    cannot have (each flat row is its own contiguous allocation, shared
    prefix or not).  Pairwise-comparison oracle, O((B·n_dir)²·leaf) —
    the serving meter's numpy twin (``cost_model._np_row_lines_shared``)
    is the hot-path implementation and is pinned equal by tests.
    """
    b, maxp = flat.shape
    assert maxp % leaf_size == 0, (maxp, leaf_size)
    n_dir = maxp // leaf_size
    leaves = flat.reshape(b * n_dir, leaf_size)
    mapped = leaves >= 0
    valid = mapped.any(-1)
    same = ((leaves[:, None, :] == leaves[None, :, :]).all(-1)
            & valid[:, None] & valid[None, :])
    j = jnp.arange(b * n_dir)
    dup = (same & (j[:, None] > j[None, :])).any(-1)
    leaf_lines = jnp.where(valid & ~dup, _lines_of(mapped), 0)
    dir_valid = valid.reshape(b, n_dir)
    return (_lines_of(dir_valid)
            + leaf_lines.reshape(b, n_dir).sum(-1)).astype(jnp.int32)


def count_segment_lines(flat: jnp.ndarray) -> jnp.ndarray:
    """SEGMENT org line count for a flat row, (...,) int32: one range
    descriptor per maximal run of *physically contiguous* mapped pages
    (phys[i+1] == phys[i] + 1), :data:`RANGES_PER_LINE` descriptors per
    64B line.  A perfectly contiguous row costs 1 line however long;
    cost scales with fragmentation (run count), not row length — the
    range-table story."""
    mapped = flat >= 0
    nd = flat.ndim
    pad_cfg = [(0, 0)] * (nd - 1) + [(1, 0)]
    prev_m = jnp.pad(mapped[..., :-1], pad_cfg, constant_values=False)
    prev_p = jnp.pad(flat[..., :-1], pad_cfg, constant_values=-2)
    new_run = mapped & (~prev_m | (flat != prev_p + 1))
    runs = new_run.sum(-1)
    return ((runs + RANGES_PER_LINE - 1) // RANGES_PER_LINE
            ).astype(jnp.int32)


def count_inverted_lines(flat: jnp.ndarray) -> jnp.ndarray:
    """INVERTED org line count for a flat row, (...,) int32: every
    mapped page's entry lives in its own hashed bucket line, so nothing
    ever shares a line — the locality-free worst case a rebuild pays."""
    return (flat >= 0).sum(-1).astype(jnp.int32)


def translate_all_costed(table, mode: str
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`translate_all` (bit-exact) plus
    :func:`count_pte_lines` — the costed rebuild used by the
    translation-metered serving path."""
    return translate_all(table, mode), count_pte_lines(table, mode)


def translate_one_costed(table, seq_idx: jnp.ndarray,
                         logical_page: jnp.ndarray, mode: str
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`translate_one` plus touched-PTE-line counts, (B,) int32:
    a flat lookup reads one line; a radix lookup reads a directory line
    and — when the directory entry is mapped — the leaf's line."""
    phys = translate_one(table, seq_idx, logical_page, mode)
    if mode == FLAT:
        return phys, jnp.ones_like(seq_idx, jnp.int32)
    if mode == RADIX:
        leaf_id = table.directory[seq_idx,
                                  logical_page // table.leaf_size]
        return phys, jnp.where(leaf_id >= 0, 2, 1).astype(jnp.int32)
    raise ValueError(mode)


def flatten_radix(table: RadixTable) -> jnp.ndarray:
    """The NDPage merge: collapse directory+leaves into one flat table."""
    return translate_all(table, RADIX)


def radix_from_flat(flat: jnp.ndarray, leaf_size: int) -> RadixTable:
    """Build the 2-level organization of an existing mapping (baseline)."""
    b, maxp = flat.shape
    assert maxp % leaf_size == 0, (maxp, leaf_size)
    n_dir = maxp // leaf_size
    leaves = flat.reshape(b * n_dir, leaf_size)
    directory = jnp.arange(b * n_dir, dtype=jnp.int32).reshape(b, n_dir)
    # unallocated directories (all-hole leaves) marked -1
    empty = (leaves < 0).all(axis=1).reshape(b, n_dir)
    directory = jnp.where(empty, -1, directory)
    return RadixTable(directory=directory, leaves=leaves)


def table_bytes(table, mode: str) -> int:
    if mode == FLAT:
        return table.size * 4
    return table.directory.size * 4 + table.leaves.size * 4


def occupancy(flat: jnp.ndarray, lengths: jnp.ndarray, page_size: int
              ) -> jnp.ndarray:
    """Fraction of mapped slots actually in use (Observation B metric)."""
    used_pages = -(-lengths // page_size)            # ceil
    mapped = (flat >= 0).sum(axis=1)
    return used_pages / jnp.maximum(mapped, 1)
