"""NDPage core: the paper's contribution.

Two faces of the same idea:
  * ``page_table``: functional models of x86-style page-table walks
    (radix-4, NDPage flattened L2/L1, huge-page, elastic-cuckoo) that the
    architectural simulator (repro.sim) replays for the faithful
    reproduction.
  * ``block_table`` / ``kv_page_manager``: the serving-side translation
    layer — logical KV positions -> physical KV pages — where the NDPage
    mechanisms (flattened table, metadata bypass via scalar prefetch) are a
    first-class feature of the TPU framework.
"""
from repro.core import block_table, kv_page_manager, page_table  # noqa: F401
