"""Software translation cache for the serving scheduler (the PWC analogue).

NDPage keeps page-walk caches for the two upper levels (hit rates ~100% /
98.6%) while the flattened bottom level goes straight to memory.  In the
serving runtime the analogous hot metadata is the *directory row* of a
sequence (radix mode) or the flat-table row (flat mode): the scheduler
resolves logical->physical pages on the host when building kernel operands,
and this LRU cache avoids re-deriving rows for sequences whose mapping did
not change between steps (prefix-shared and continuing sequences).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np


class TranslationCache:
    """LRU cache over (seq_id, version) -> np.ndarray physical-page rows."""

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._store: "OrderedDict[Tuple[Hashable, int], np.ndarray]" = (
            OrderedDict())
        self.hits = 0
        self.misses = 0

    def lookup(self, seq_id: Hashable, version: int) -> Optional[np.ndarray]:
        key = (seq_id, version)
        row = self._store.get(key)
        if row is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return row

    def insert(self, seq_id: Hashable, version: int, row: np.ndarray) -> None:
        key = (seq_id, version)
        self._store[key] = row
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)

    def invalidate(self, seq_id: Hashable) -> None:
        for key in [k for k in self._store if k[0] == seq_id]:
            del self._store[key]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
