"""Software translation cache for the serving scheduler (the PWC analogue).

NDPage keeps page-walk caches for the two upper levels (hit rates ~100% /
98.6%) while the flattened bottom level goes straight to memory.  In the
serving runtime the analogous hot metadata is the *directory row* of a
sequence (radix mode) or the flat-table row (flat mode): the scheduler
resolves logical->physical pages on the host when building kernel operands,
and this LRU cache avoids re-deriving rows for sequences whose mapping did
not change between steps (prefix-shared and continuing sequences).

The cache OWNS the per-sequence version counter: callers ask
:meth:`version` for the current one, :meth:`bump` it when a mapping
grows, and :meth:`invalidate` both evicts the rows and bumps — so a
recycled ``seq_id`` (request ids are caller-chosen) can never hit a
stale row even if the caller's own bookkeeping restarts from zero.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Optional, Tuple

import numpy as np


class TranslationCache:
    """LRU cache over (seq_id, version) -> np.ndarray physical-page rows."""

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._store: "OrderedDict[Tuple[Hashable, int], np.ndarray]" = (
            OrderedDict())
        #: versions of LIVE sequences only (bounded by the live set —
        #: invalidate() pops the entry); untracked ids default to the
        #: monotone floor below, which invalidate() raises past every
        #: version the retiring sequence ever used
        self._versions: Dict[Hashable, int] = {}
        self._floor = 0
        self.hits = 0
        self.misses = 0

    # -- versions -------------------------------------------------------------
    def version(self, seq_id: Hashable) -> int:
        """Current mapping version of ``seq_id`` (the monotone floor
        for ids not currently tracked)."""
        return self._versions.get(seq_id, self._floor)

    def bump(self, seq_id: Hashable) -> int:
        """Advance ``seq_id``'s version (the mapping changed); rows
        cached under older versions become unreachable and age out of
        the LRU."""
        self._versions[seq_id] = self.version(seq_id) + 1
        return self._versions[seq_id]

    # -- rows -----------------------------------------------------------------
    def lookup(self, seq_id: Hashable,
               version: Optional[int] = None) -> Optional[np.ndarray]:
        key = (seq_id, self.version(seq_id) if version is None else version)
        row = self._store.get(key)
        if row is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return row

    def insert(self, seq_id: Hashable, version: Optional[int],
               row: np.ndarray) -> None:
        if version is None:
            # pin the id's version so a LATER floor raise (another
            # sequence retiring) cannot orphan this live row
            version = self._versions.setdefault(seq_id,
                                                self.version(seq_id))
        key = (seq_id, version)
        self._store[key] = row
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)

    def invalidate(self, seq_id: Hashable) -> None:
        """Evict every cached row of ``seq_id`` AND advance past its
        versions: eviction alone is not enough, because a later
        sequence reusing the id at version 0 would otherwise race a
        concurrent insert for the same (seq_id, 0) key.  The id's
        tracking entry is dropped (the dict stays bounded by the live
        set) and the shared floor raised past every version it used —
        a recycled id restarts above them.

        Invalidating an id that was never admitted (no cached rows, no
        version entry) is a pure no-op: raising the floor for it would
        desynchronize EVERY untracked id's version for no benefit —
        retry/eviction paths may double-invalidate freely."""
        had_rows = False
        for key in [k for k in self._store if k[0] == seq_id]:
            del self._store[key]
            had_rows = True
        if had_rows or seq_id in self._versions:
            self._floor = max(self._floor, self.version(seq_id) + 1)
            self._versions.pop(seq_id, None)

    @property
    def hit_rate(self) -> float:
        """Hits / lookups; 0.0 on a fresh cache (never divides by zero)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
