"""Functional page-table models: the PTE access streams of each mechanism.

The simulator replays virtual-page-number (VPN) traces; each mechanism maps
a VPN to the *sequence of PTE cache-line addresses* a hardware page walk
would touch.  Addresses are synthetic-physical **64B-line ids** (int32,
inside a dedicated page-table region above ``PT_REGION_LINE``) but preserve
exactly the locality structure that drives cache/TLB behaviour:

  radix-4     4 sequential accesses; PTEs of adjacent VPNs share cache lines
              (8 x 8B PTEs / 64B line); node placement is a hash of the VPN
              prefix (nodes are 4KB-scattered in physical memory).
  ndpage      3 sequential accesses; levels L2/L1 merged into one 2MB node
              indexed by the low 18 VPN bits (the paper's flattened table).
  hugepage    3 sequential accesses (2MB pages, no PL1); TLB entries span
              2MB of VA.
  ech         3 *parallel* cuckoo-hash probes (Elastic Cuckoo Hash Table);
              latency is max(), not sum() — modelled by the MMU.
  ideal       no PTE accesses at all.

All functions are vectorized over the trace axis and jit-friendly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

PTE_BYTES = 8
LINE_BYTES = 64
PTES_PER_LINE = LINE_BYTES // PTE_BYTES          # 8
ENTRIES = 512                                    # per 4KB radix node
NODE_LINES = ENTRIES // PTES_PER_LINE            # 64 lines per 4KB node
FLAT_LINES = (1 << 18) // PTES_PER_LINE          # 32768 lines per 2MB node
PT_REGION_LINE = 1 << 28                         # PT region starts here

# VPN bit slices (48-bit VA, 4KB pages -> 36-bit VPN; traces use <= 2^22)
#   L1 idx: bits 0..8 | L2: 9..17 | L3: 18..26 | L4: 27..35
_SHIFTS = (27, 18, 9, 0)                         # L4, L3, L2, L1


def _mix(x: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Cheap deterministic integer hash (Wang-style), uint32."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(salt)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _node_base_line(node_key: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Pseudo-random 4KB-aligned node placement: line id of node start."""
    h = _mix(node_key, salt)
    # 2^20 possible node frames (4GB of PT space), 64 lines each
    return ((h & jnp.uint32(0xFFFFF)).astype(jnp.int32)) * NODE_LINES


def _level_line(vpn: jnp.ndarray, shift: int, salt: int) -> jnp.ndarray:
    idx = (vpn >> shift) & (ENTRIES - 1)
    prefix = (vpn >> (shift + 9)).astype(jnp.int32)
    base = _node_base_line(prefix, salt)
    return PT_REGION_LINE + base + (idx // PTES_PER_LINE).astype(jnp.int32)


def radix4_walk_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """PTE line ids for a 4-level walk. vpn: (T,) int32 -> (T, 4)."""
    return jnp.stack([_level_line(vpn, sh, 0xA0 + i)
                      for i, sh in enumerate(_SHIFTS)], axis=-1)


def ndpage_walk_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """NDPage: L4, L3, then ONE flattened L2/L1 access. (T,) -> (T, 3)."""
    out = [_level_line(vpn, sh, 0xA0 + i) for i, sh in enumerate(_SHIFTS[:2])]
    idx18 = (vpn & ((1 << 18) - 1)).astype(jnp.int32)
    prefix = (vpn >> 18).astype(jnp.int32)
    h = _mix(prefix, 0xF1)
    base = ((h & jnp.uint32(0x3F)).astype(jnp.int32)) * FLAT_LINES
    out.append(PT_REGION_LINE + base + idx18 // PTES_PER_LINE)
    return jnp.stack(out, axis=-1)


def ndpage_pl3_walk_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """Flattened-PL3 NDPage variant: L4, then ONE node merging L3/L2/L1
    (2^27 PTEs of 4KB pages = 512GB of VA per node). (T,) -> (T, 2)."""
    out = [_level_line(vpn, _SHIFTS[0], 0xA0)]
    idx27 = (vpn & ((1 << 27) - 1)).astype(jnp.int32)
    prefix = (vpn >> 27).astype(jnp.int32)
    h = _mix(prefix, 0xF7)
    # 8 possible giant nodes of 2^24 lines each (region stays in int32)
    base = ((h & jnp.uint32(0x7)).astype(jnp.int32)) * ((1 << 27)
                                                        // PTES_PER_LINE)
    out.append(PT_REGION_LINE + base + idx27 // PTES_PER_LINE)
    return jnp.stack(out, axis=-1)


def hugepage_walk_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """2MB pages: PL4, PL3, PL2 only. (T,) -> (T, 3)."""
    return jnp.stack([_level_line(vpn, sh, 0xB0 + i)
                      for i, sh in enumerate(_SHIFTS[:3])], axis=-1)


def ech_probe_lines(vpn: jnp.ndarray, num_ways: int = 2) -> jnp.ndarray:
    """Elastic cuckoo hashing: d independent hashed probes. (T,) -> (T, d)."""
    outs = []
    for w in range(num_ways):
        h = _mix(vpn.astype(jnp.uint32), salt=0xC0 + w)
        # each way is a large hash table: 2^24 line-granular buckets
        line = (h & jnp.uint32(0x00FFFFFF)).astype(jnp.int32)
        outs.append(PT_REGION_LINE + (1 << 24) * (w + 1) + line)
    return jnp.stack(outs, axis=-1)


def inverted_hash_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """Picorel-style near-memory inverted page table: ONE set-associative
    hashed bucket per lookup, no radix levels. (T,) -> (T, 1).

    The bucket's ways share one 64B line, so a lookup is a single line
    access whatever the associativity; vpns colliding into a bucket are
    resolved within the line (the open-addressing spill is modelled by
    ``inverted_table_insert`` for analysis, not the timing walk).
    """
    h = _mix(vpn.astype(jnp.uint32), salt=0xD5)
    # 2^22 line-granular buckets in their own slice of the PT region
    line = (h & jnp.uint32(0x003FFFFF)).astype(jnp.int32)
    return (PT_REGION_LINE + (5 << 24) + line)[..., None]


#: binary-search probes per range lookup (covers 2^12 extent ranks)
RANGE_PROBES = 4
#: 16B range descriptors (base, limit, target) -> 4 per 64B line
RANGES_PER_LINE = 4
#: pages per contiguous extent rank (2MB extents of 4KB pages)
RANGE_EXTENT_SHIFT = 9


def range_walk_lines(vpn: jnp.ndarray) -> jnp.ndarray:
    """Range/segment-table translation: a binary search over sorted
    range descriptors (the ``AddrTrans`` idiom). (T,) -> (T, 4).

    Probe d looks at the search midpoint whose low ``keep`` rank bits
    are cleared — early probes collapse onto a handful of descriptor
    lines (the root of the binary search, effectively always cached),
    later probes spread with the workload's extent fragmentation, so
    the *miss* cost scales with log2(ranges)/fragmentation rather than
    a fixed radix depth.
    """
    rank = (vpn >> RANGE_EXTENT_SHIFT).astype(jnp.int32)
    outs = []
    for d in range(RANGE_PROBES):
        keep = 3 * (RANGE_PROBES - 1 - d)
        idx = ((rank >> keep) << keep)
        outs.append(PT_REGION_LINE + (6 << 24) + idx // RANGES_PER_LINE)
    return jnp.stack(outs, axis=-1)


# ---------------------------------------------------------------------------
# host-side reference models for the zoo walks (property-test oracles +
# zoo-benchmark occupancy/collision analysis; numpy, not jitted)
# ---------------------------------------------------------------------------
def _hash_np(x: np.ndarray, salt: int = 0xD5) -> np.ndarray:
    """Numpy twin of ``_mix`` (same constants, same results).  Always
    works on arrays: numpy warns on scalar uint32 overflow but wraps
    array elements silently, which is the semantics we want."""
    x = np.asarray(x).astype(np.uint32) ^ np.uint32(salt)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def inverted_table_insert(vpns: np.ndarray, log2_slots: int = 22
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Insert distinct vpns into an open-addressed inverted table.

    Returns ``(slots, probes)``: the slot each vpn landed in (linear
    probing from its hashed home) and the number of extra probes it
    paid (0 = landed in its home slot).  Invariants the property tests
    pin: no two live vpns share a slot, and a vpn pays probes > 0 iff
    its home slot was already taken — aliasing is never silent.
    """
    vpns = np.asarray(vpns, dtype=np.int64)
    if len(np.unique(vpns)) != len(vpns):
        raise ValueError("inverted_table_insert requires distinct vpns")
    n_slots = 1 << log2_slots
    if len(vpns) > n_slots:
        raise ValueError("more vpns than slots")
    occupied: set = set()
    slots = np.empty(len(vpns), np.int64)
    probes = np.empty(len(vpns), np.int64)
    homes = _hash_np(vpns) & np.uint32(n_slots - 1)
    for i, home in enumerate(homes):
        s, p = int(home), 0
        while s in occupied:
            s = (s + 1) & (n_slots - 1)
            p += 1
        occupied.add(s)
        slots[i], probes[i] = s, p
    return slots, probes


def range_table_lookup(starts: np.ndarray, lengths: np.ndarray,
                       targets: np.ndarray, addrs: np.ndarray
                       ) -> np.ndarray:
    """Binary-search lookup over sorted non-overlapping ranges.

    ``starts`` must be sorted ascending; range i covers
    [starts[i], starts[i] + lengths[i]).  Returns the translated
    address ``targets[i] + (addr - starts[i])`` per addr, or -1 when no
    range covers it.  This is the production-shaped lookup
    (np.searchsorted == the binary search); the linear oracle below is
    what the hypothesis test pins it against.
    """
    starts = np.asarray(starts, np.int64)
    lengths = np.asarray(lengths, np.int64)
    targets = np.asarray(targets, np.int64)
    addrs = np.asarray(addrs, np.int64)
    idx = np.searchsorted(starts, addrs, side="right") - 1
    safe = np.maximum(idx, 0)
    inside = ((idx >= 0)
              & (addrs < starts[safe] + lengths[safe]))
    return np.where(inside, targets[safe] + (addrs - starts[safe]),
                    np.int64(-1))


def range_table_lookup_linear(starts: np.ndarray, lengths: np.ndarray,
                              targets: np.ndarray, addrs: np.ndarray
                              ) -> np.ndarray:
    """Linear-scan oracle for ``range_table_lookup`` (O(ranges) per
    addr; correctness reference only)."""
    starts = np.asarray(starts, np.int64)
    lengths = np.asarray(lengths, np.int64)
    targets = np.asarray(targets, np.int64)
    out = np.full(len(np.atleast_1d(addrs)), -1, np.int64)
    for j, a in enumerate(np.atleast_1d(np.asarray(addrs, np.int64))):
        for i in range(len(starts)):
            if starts[i] <= a < starts[i] + lengths[i]:
                out[j] = targets[i] + (a - starts[i])
                break
    return out


# ---------------------------------------------------------------------------
# occupancy analysis (paper Fig. 8): computed from the VPN working set
# ---------------------------------------------------------------------------
def occupancy_by_level(vpns: np.ndarray) -> Tuple[float, float, float, float]:
    """(PL4, PL3, PL2, PL1) occupancy of a workload's touched VPN set.

    Occupancy of level k = touched entries / (ENTRIES * touched nodes):
    exactly the paper's metric — how full the allocated tables are.
    """
    vpns = np.unique(np.asarray(vpns, dtype=np.int64))
    occs = []
    for sh in _SHIFTS:
        entries = np.unique(vpns >> sh)            # distinct entries touched
        tables = np.unique(vpns >> (sh + 9))       # distinct nodes touched
        occs.append(len(entries) / (ENTRIES * max(len(tables), 1)))
    return tuple(occs)  # type: ignore[return-value]


def flattened_occupancy(vpns: np.ndarray) -> float:
    """Occupancy of the merged L2/L1 node (2^18 entries)."""
    vpns = np.unique(np.asarray(vpns, dtype=np.int64))
    entries = np.unique(vpns)                      # each vpn = one entry
    tables = np.unique(vpns >> 18)
    return len(entries) / ((1 << 18) * max(len(tables), 1))


WALKS = {
    "radix": radix4_walk_lines,
    "ndpage": ndpage_walk_lines,
    "ndpage_pl3": ndpage_pl3_walk_lines,
    "hugepage": hugepage_walk_lines,
    "ech": ech_probe_lines,
    "inverted": inverted_hash_lines,
    "range": range_walk_lines,
}
