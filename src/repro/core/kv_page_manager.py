"""Paged KV-cache manager: host-side allocator + jit-side page primitives.

The host allocator (PagePool / KVPageManager) plays the OS role: it owns the
free list, maps logical pages of live sequences to physical pages, and
decides the table organization (radix 2-level vs NDPage flat) from measured
occupancy — the paper's Observation B applied at runtime.  Allocation never
happens inside jit; decode steps consume a ready table, exactly as a page
walk consumes OS-built page tables.

jit-side primitives (`append_kv`, `gather_kv`) are the data-path half used
by models/attention and by the kernels' reference oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_table as BT


# ---------------------------------------------------------------------------
# host-side allocator (the "OS")
# ---------------------------------------------------------------------------
class PagePool:
    """Refcounted free-list allocator over a fixed pool of physical KV
    pages.

    Pages come out of :meth:`allocate` with refcount 1; prefix-sharing
    sequences take additional references via :meth:`share` and every
    holder calls :meth:`release` — the page returns to the free list
    only when the LAST reference drops, so evicting one sharer can
    never free a page another live sequence still maps.

    The ``*_array`` variants are the fleet path: one numpy round-trip
    for a whole batch of pages instead of a per-page Python loop.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref = np.zeros(num_pages, np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def allocate(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: want {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._ref[out] = 1
        return out

    def share(self, pages: List[int]) -> None:
        """Take one additional reference on each (already-allocated)
        page — the prefix-sharing admission path."""
        self._ref[list(pages)] += 1

    def release(self, pages: List[int]) -> None:
        """Drop one reference per page; pages reaching refcount 0 go
        back to the free list."""
        self.release_array(np.asarray(list(pages), np.int64))

    # -- batched (fleet) variants -------------------------------------------
    def allocate_array(self, n: int) -> np.ndarray:
        """Allocate ``n`` pages as one int32 array (refcount 1 each)."""
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: want {n}, have {len(self._free)}")
        if n == 0:
            return np.empty(0, np.int32)
        out = np.asarray(self._free[-n:], np.int32)[::-1].copy()
        del self._free[-n:]
        self._ref[out] = 1
        return out

    def share_array(self, pages: np.ndarray) -> None:
        np.add.at(self._ref, np.asarray(pages, np.int64), 1)

    def release_array(self, pages: np.ndarray) -> None:
        """Vectorized :meth:`release`: handles one batch containing the
        same shared page several times (several retiring sharers)."""
        pages = np.asarray(pages, np.int64)
        if pages.size == 0:
            return
        np.add.at(self._ref, pages, -1)
        uniq = np.unique(pages)
        if (self._ref[uniq] < 0).any():
            bad = uniq[self._ref[uniq] < 0]
            raise ValueError(f"double free of pages {bad.tolist()}")
        freed = uniq[self._ref[uniq] == 0]
        self._free.extend(int(p) for p in freed)


class KVPageManager:
    """Logical->physical page mapping for a batch of sequences.

    Mirrors NDPage's design point: it maintains the mapping as a 2-level
    radix structure (directory of leaf tables) and *flattens* it when the
    measured leaf occupancy crosses ``flatten_threshold`` — after which
    decode kernels get the single-indirection flat table.
    """

    def __init__(self, num_pages: int, page_size: int, max_seqs: int,
                 max_len: int, leaf_size: int = 16,
                 flatten_threshold: float = 0.5):
        self.pool = PagePool(num_pages)
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.max_pages = -(-max_len // page_size)
        self.leaf_size = leaf_size
        self.flatten_threshold = flatten_threshold
        # host mapping: per-seq list of physical pages
        self.pages: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        # translation stats (the serving analogue of PTW counters)
        self.stats = {"allocated_pages": 0, "freed_pages": 0,
                      "flattens": 0, "table_rebuilds": 0}

    # -- sequence lifecycle -------------------------------------------------
    def add_sequence(self, seq_id: int, prompt_len: int,
                     shared_pages: Optional[List[int]] = None) -> None:
        """Map ``prompt_len`` tokens for ``seq_id``.  ``shared_pages``
        (prefix sharing) seeds the first logical pages from an
        already-live prefix: the pool takes an extra reference on each
        instead of allocating, so sharers hold the same physical pages
        and :meth:`free_sequence` of one sharer never frees them out
        from under another."""
        n = -(-max(prompt_len, 1) // self.page_size)
        shared = list(shared_pages or [])[:n]
        if shared:
            self.pool.share(shared)
        try:
            fresh = self.pool.allocate(n - len(shared))
        except MemoryError:
            if shared:                    # unwind the references we took
                self.pool.release(shared)
            raise
        self.pages[seq_id] = shared + fresh
        self.lengths[seq_id] = prompt_len
        self.stats["allocated_pages"] += n - len(shared)

    def append_token(self, seq_id: int) -> None:
        """Grow mapping by one token; allocate a page on boundary cross."""
        self.lengths[seq_id] += 1
        need = -(-self.lengths[seq_id] // self.page_size)
        have = len(self.pages[seq_id])
        if need > have:
            self.pages[seq_id].extend(self.pool.allocate(need - have))
            self.stats["allocated_pages"] += need - have

    def free_sequence(self, seq_id: int) -> None:
        pages = self.pages.pop(seq_id)
        self.pool.release(pages)
        self.stats["freed_pages"] += len(pages)
        del self.lengths[seq_id]

    # -- occupancy & table organization (the NDPage decision) ---------------
    def occupancy(self) -> float:
        """Used slots / mapped slots across live sequences."""
        used = sum(self.lengths.values())
        mapped = sum(len(p) for p in self.pages.values()) * self.page_size
        return used / mapped if mapped else 0.0

    def preferred_mode(self) -> str:
        return (BT.FLAT if self.occupancy() >= self.flatten_threshold
                else BT.RADIX)

    # -- device-table construction -------------------------------------------
    def flat_table(self, seq_ids: List[int]) -> jnp.ndarray:
        """(B, max_pages) int32; -1 where unmapped."""
        self.stats["table_rebuilds"] += 1
        tab = np.full((len(seq_ids), self.max_pages), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            p = self.pages[sid]
            tab[i, : len(p)] = p
        return jnp.asarray(tab)

    def radix_table(self, seq_ids: List[int]) -> BT.RadixTable:
        flat = self.flat_table(seq_ids)
        return BT.radix_from_flat(flat, min(self.leaf_size, self.max_pages))

    def build_table(self, seq_ids: List[int], mode: Optional[str] = None):
        mode = mode or self.preferred_mode()
        if mode == BT.FLAT:
            self.stats["flattens"] += 1
            return self.flat_table(seq_ids), BT.FLAT
        return self.radix_table(seq_ids), BT.RADIX

    def lengths_array(self, seq_ids: List[int]) -> jnp.ndarray:
        return jnp.asarray([self.lengths[s] for s in seq_ids], jnp.int32)


# ---------------------------------------------------------------------------
# jit-side page primitives (data path)
# ---------------------------------------------------------------------------
def append_kv(kp: jnp.ndarray, vp: jnp.ndarray, k_new: jnp.ndarray,
              v_new: jnp.ndarray, phys_page: jnp.ndarray,
              slot: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter one new token's K/V into the pools.

    kp/vp: (N, page, K, H); k_new/v_new: (B, K, H); phys_page, slot: (B,).
    """
    kp = kp.at[phys_page, slot].set(k_new)
    vp = vp.at[phys_page, slot].set(v_new)
    return kp, vp


def gather_kv(kp: jnp.ndarray, vp: jnp.ndarray, phys: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize per-sequence KV from pools (the XLA reference path).

    phys: (B, max_pages) -> (B, max_pages*page, K, H).
    On real TPU the Pallas kernel replaces this (pages stream HBM->VMEM
    block-by-block; the table itself rides the scalar-prefetch path).
    """
    safe = jnp.maximum(phys, 0)
    b, mp = phys.shape
    n, pg, kh, hd = kp.shape
    ks = kp[safe].reshape(b, mp * pg, kh, hd)
    vs = vp[safe].reshape(b, mp * pg, kh, hd)
    return ks, vs


def prefill_into_pages(kp, vp, k_seq, v_seq, phys: jnp.ndarray):
    """Write a prefilled (B, S, K, H) K/V into pools. S % page == 0 assumed
    (caller pads); phys: (B, n_pages_used)."""
    b, s, kh, hd = k_seq.shape
    pg = kp.shape[1]
    npg = s // pg
    kr = k_seq.reshape(b, npg, pg, kh, hd)
    vr = v_seq.reshape(b, npg, pg, kh, hd)
    idx = jnp.maximum(phys[:, :npg], 0)
    kp = kp.at[idx].set(kr)
    vp = vp.at[idx].set(vr)
    return kp, vp
