"""Paged KV-cache manager: host-side allocator + jit-side page primitives.

The host allocator (PagePool / KVPageManager) plays the OS role: it owns the
free list, maps logical pages of live sequences to physical pages, and
decides the table organization (radix 2-level vs NDPage flat) from measured
occupancy — the paper's Observation B applied at runtime.  Allocation never
happens inside jit; decode steps consume a ready table, exactly as a page
walk consumes OS-built page tables.

jit-side primitives (`append_kv`, `gather_kv`) are the data-path half used
by models/attention and by the kernels' reference oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_table as BT


# ---------------------------------------------------------------------------
# host-side allocator (the "OS")
# ---------------------------------------------------------------------------
class PagePool:
    """Free-list allocator over a fixed pool of physical KV pages."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def allocate(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: want {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        return out

    def release(self, pages: List[int]) -> None:
        self._free.extend(pages)


class KVPageManager:
    """Logical->physical page mapping for a batch of sequences.

    Mirrors NDPage's design point: it maintains the mapping as a 2-level
    radix structure (directory of leaf tables) and *flattens* it when the
    measured leaf occupancy crosses ``flatten_threshold`` — after which
    decode kernels get the single-indirection flat table.
    """

    def __init__(self, num_pages: int, page_size: int, max_seqs: int,
                 max_len: int, leaf_size: int = 16,
                 flatten_threshold: float = 0.5):
        self.pool = PagePool(num_pages)
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.max_pages = -(-max_len // page_size)
        self.leaf_size = leaf_size
        self.flatten_threshold = flatten_threshold
        # host mapping: per-seq list of physical pages
        self.pages: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        # translation stats (the serving analogue of PTW counters)
        self.stats = {"allocated_pages": 0, "freed_pages": 0,
                      "flattens": 0, "table_rebuilds": 0}

    # -- sequence lifecycle -------------------------------------------------
    def add_sequence(self, seq_id: int, prompt_len: int) -> None:
        n = -(-max(prompt_len, 1) // self.page_size)
        self.pages[seq_id] = self.pool.allocate(n)
        self.lengths[seq_id] = prompt_len
        self.stats["allocated_pages"] += n

    def append_token(self, seq_id: int) -> None:
        """Grow mapping by one token; allocate a page on boundary cross."""
        self.lengths[seq_id] += 1
        need = -(-self.lengths[seq_id] // self.page_size)
        have = len(self.pages[seq_id])
        if need > have:
            self.pages[seq_id].extend(self.pool.allocate(need - have))
            self.stats["allocated_pages"] += need - have

    def free_sequence(self, seq_id: int) -> None:
        pages = self.pages.pop(seq_id)
        self.pool.release(pages)
        self.stats["freed_pages"] += len(pages)
        del self.lengths[seq_id]

    # -- occupancy & table organization (the NDPage decision) ---------------
    def occupancy(self) -> float:
        """Used slots / mapped slots across live sequences."""
        used = sum(self.lengths.values())
        mapped = sum(len(p) for p in self.pages.values()) * self.page_size
        return used / mapped if mapped else 0.0

    def preferred_mode(self) -> str:
        return (BT.FLAT if self.occupancy() >= self.flatten_threshold
                else BT.RADIX)

    # -- device-table construction -------------------------------------------
    def flat_table(self, seq_ids: List[int]) -> jnp.ndarray:
        """(B, max_pages) int32; -1 where unmapped."""
        self.stats["table_rebuilds"] += 1
        tab = np.full((len(seq_ids), self.max_pages), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            p = self.pages[sid]
            tab[i, : len(p)] = p
        return jnp.asarray(tab)

    def radix_table(self, seq_ids: List[int]) -> BT.RadixTable:
        flat = self.flat_table(seq_ids)
        return BT.radix_from_flat(flat, min(self.leaf_size, self.max_pages))

    def build_table(self, seq_ids: List[int], mode: Optional[str] = None):
        mode = mode or self.preferred_mode()
        if mode == BT.FLAT:
            self.stats["flattens"] += 1
            return self.flat_table(seq_ids), BT.FLAT
        return self.radix_table(seq_ids), BT.RADIX

    def lengths_array(self, seq_ids: List[int]) -> jnp.ndarray:
        return jnp.asarray([self.lengths[s] for s in seq_ids], jnp.int32)


# ---------------------------------------------------------------------------
# jit-side page primitives (data path)
# ---------------------------------------------------------------------------
def append_kv(kp: jnp.ndarray, vp: jnp.ndarray, k_new: jnp.ndarray,
              v_new: jnp.ndarray, phys_page: jnp.ndarray,
              slot: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter one new token's K/V into the pools.

    kp/vp: (N, page, K, H); k_new/v_new: (B, K, H); phys_page, slot: (B,).
    """
    kp = kp.at[phys_page, slot].set(k_new)
    vp = vp.at[phys_page, slot].set(v_new)
    return kp, vp


def gather_kv(kp: jnp.ndarray, vp: jnp.ndarray, phys: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize per-sequence KV from pools (the XLA reference path).

    phys: (B, max_pages) -> (B, max_pages*page, K, H).
    On real TPU the Pallas kernel replaces this (pages stream HBM->VMEM
    block-by-block; the table itself rides the scalar-prefetch path).
    """
    safe = jnp.maximum(phys, 0)
    b, mp = phys.shape
    n, pg, kh, hd = kp.shape
    ks = kp[safe].reshape(b, mp * pg, kh, hd)
    vs = vp[safe].reshape(b, mp * pg, kh, hd)
    return ks, vs


def prefill_into_pages(kp, vp, k_seq, v_seq, phys: jnp.ndarray):
    """Write a prefilled (B, S, K, H) K/V into pools. S % page == 0 assumed
    (caller pads); phys: (B, n_pages_used)."""
    b, s, kh, hd = k_seq.shape
    pg = kp.shape[1]
    npg = s // pg
    kr = k_seq.reshape(b, npg, pg, kh, hd)
    vr = v_seq.reshape(b, npg, pg, kh, hd)
    idx = jnp.maximum(phys[:, :npg], 0)
    kp = kp.at[idx].set(kr)
    vp = vp.at[idx].set(vr)
    return kp, vp
