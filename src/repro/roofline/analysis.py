"""Three-term roofline from a compiled dry-run cell.

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

Hardware constants (TPU v5e-like, per the assignment): 197 TFLOP/s bf16 per
chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Notes on sources: cost_analysis() runs on the PARTITIONED module, so flops
and bytes are per-device already; collective_bytes is parsed per-device
from the SPMD HLO.  MODEL_FLOPS uses the 6*N*D rule (6*N_active*D for MoE)
per training step, or 2*N*D for a decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro import config as C


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link


HW = Hardware()


def model_flops(cfg: C.ArchConfig, shape: C.ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (decode) with N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch                   # one token per sequence
    return 2.0 * n * tokens


def roofline_terms(cell: Dict, cfg: Optional[C.ArchConfig] = None,
                   shape: Optional[C.ShapeConfig] = None,
                   hw: Hardware = HW) -> Dict[str, float]:
    """cell: one dryrun_results.json record. Returns terms in SECONDS
    (per-device; chips already divided out by SPMD partitioning)."""
    t_compute = cell["flops"] / hw.peak_flops
    t_memory = cell["bytes_accessed"] / hw.hbm_bw
    t_coll = cell["collective_bytes"] / hw.ici_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    bound = max(terms.values())
    out = dict(terms)
    out["dominant"] = dominant.replace("_s", "")
    out["step_lower_bound_s"] = bound
    if cfg is not None and shape is not None:
        chips = 512 if cell["mesh"] == "2x16x16" else 256
        mf = model_flops(cfg, shape) / chips      # per-device useful flops
        out["model_flops_per_device"] = mf
        out["useful_flop_frac"] = (mf / cell["flops"]) if cell["flops"] else 0
        # roofline fraction: useful work at peak / achievable step time
        out["roofline_frac"] = (mf / hw.peak_flops) / bound if bound else 0.0
    return out
