from repro.roofline.hlo_stats import collective_bytes, count_collectives  # noqa: F401
from repro.roofline.analysis import roofline_terms, HW  # noqa: F401
