"""Parse collective traffic out of optimized HLO text.

``cost_analysis()`` has no collective-bytes entry, so we sum the output
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in ``compiled.as_text()`` (post-SPMD HLO: these are the
real wire transfers of one device).

Loop awareness: collectives inside a ``while`` body (scan-over-layers,
microbatch grad accumulation) execute once per iteration, so each
computation's contribution is scaled by the product of trip counts on its
call chain.  Trip counts are recovered from the loop-condition
computations (lax.scan lowers to ``compare(iter, constant(N), LT)``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")
_OP_ALT = "|".join(COLLECTIVE_OPS)
# "%x = f32[..]{..} all-reduce(" — op preceded by whitespace (not part of a
# variable name, which would have %-prefix / hyphen continuation)
_LINE_RE = re.compile(r"=.*?\s(" + _OP_ALT + r")(-start)?\(")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_CALL_ATTRS = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(
    r"\swhile\(.*body=%?([\w.\-]+),\s*condition=%?([\w.\-]+)"
    r"|\swhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str):
    """computation -> list of lines; plus the ENTRY computation name."""
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        is_hdr = (not line.startswith(" ") and stripped.endswith("{")
                  and "->" in stripped)
        if is_hdr:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if stripped.startswith("ENTRY"):
                    entry = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the loop condition computation."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_INT.findall(ln):
            best = max(best, int(c))
    return best


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    """Call-chain multiplier per computation (while bodies x trip count)."""
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return {k: 1.0 for k in comps}
    mult[entry] = 1.0
    stack = [entry]
    seen = set()
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        m = mult[name]
        for ln in comps.get(name, ()):
            wm = _WHILE_RE.search(ln)
            if wm:
                body = wm.group(1) or wm.group(4)
                cond = wm.group(2) or wm.group(3)
                trips = _trip_count(comps.get(cond, []))
                for callee in (body, cond):
                    if callee:
                        mult[callee] = max(mult[callee], m * trips)
                        stack.append(callee)
                continue
            for callee in _CALL_ATTRS.findall(ln):
                mult[callee] = max(mult[callee], m)
                stack.append(callee)
            bm = _BRANCHES.search(ln)
            if bm:
                for callee in bm.group(1).split(","):
                    callee = callee.strip().lstrip("%")
                    if callee:
                        mult[callee] = max(mult[callee], m)
                        stack.append(callee)
    for k in comps:
        mult.setdefault(k, 1.0)
    return dict(mult)


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Bytes moved per collective kind, loop-trip weighted (one device)."""
    comps, entry = _parse_computations(hlo_text)
    mult = _multipliers(comps, entry)
    out: Dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        w = mult.get(name, 1.0)
        for ln in lines:
            m = _LINE_RE.search(ln)
            if not m:
                continue
            eq = ln.find(" = ")
            if eq < 0:
                continue
            # output shape(s): the text between '=' and the matched op name
            shape_part = ln[eq + 3: m.start(1)]
            out[m.group(1)] += _shape_bytes(shape_part) * w
    return dict(out)


def collective_bytes(hlo_text: str) -> float:
    return float(sum(collective_stats(hlo_text).values()))


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m:
            counts[m.group(1)] += 1
    return dict(counts)


# ---------------------------------------------------------------------------
# loop-aware FLOP counting (cost_analysis() visits while bodies only ONCE,
# so scan-over-layers / grad-accum flops must be recovered from the HLO)
# ---------------------------------------------------------------------------
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+(\w[\w\-]*)\(")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"\(([^)]*)\)")


def _shape_dims(shape_text: str):
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def dot_flops(hlo_text: str) -> Tuple[float, float]:
    """(loop_weighted_flops, unweighted_flops) summed over dot ops.

    flops(dot) = 2 * result_elements * contracted_size; operand shapes are
    resolved from their defining lines within the same computation.
    """
    comps, entry = _parse_computations(hlo_text)
    mult = _multipliers(comps, entry)
    weighted = 0.0
    raw = 0.0
    for name, lines in comps.items():
        w = mult.get(name, 1.0)
        shapes: Dict[str, List[int]] = {}
        pending = []
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            var, shape_txt, op = dm.groups()
            dims = _shape_dims(shape_txt)
            if dims is not None:
                shapes[var] = dims
            if op == "dot":
                pending.append((ln, dims))
        for ln, result_dims in pending:
            if result_dims is None:
                continue
            ops_m = _OPERANDS.search(ln[ln.find("dot("):])
            cdims_m = _DOT_DIMS.search(ln)
            contract = 1
            if ops_m and cdims_m:
                operands = [o.strip().lstrip("%")
                            for o in ops_m.group(1).split(",")]
                lhs = shapes.get(operands[0]) if operands else None
                if lhs is not None and cdims_m.group(1):
                    for d in cdims_m.group(1).split(","):
                        di = int(d)
                        if di < len(lhs):
                            contract *= lhs[di]
            result_elems = 1
            for d in result_dims:
                result_elems *= d
            f = 2.0 * result_elems * contract
            weighted += f * w
            raw += f
    return weighted, raw
