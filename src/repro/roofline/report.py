"""Render the §Roofline table from dryrun_results.json.

Usage: PYTHONPATH=src python -m repro.roofline.report [path] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

from repro import config as C
from repro.roofline.analysis import HW, roofline_terms


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:6.1f}ms"
    return f"{x * 1e6:6.1f}us"


def build_rows(results: Dict, mesh: str):
    rows = []
    for key, cell in sorted(results.items()):
        if cell["mesh"] != mesh:
            continue
        cfg = C.get_arch(cell["arch"])
        shape = C.SHAPES[cell["shape"]]
        t = roofline_terms(cell, cfg, shape)
        rows.append((cell["arch"], cell["shape"], t, cell))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="dryrun_results.json")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    with open(args.path) as f:
        results = json.load(f)
    rows = build_rows(results, args.mesh)

    sep = "|" if args.markdown else "  "
    hdr = (f"{'arch':24s}{sep}{'shape':12s}{sep}{'compute':>9s}{sep}"
           f"{'memory':>9s}{sep}{'collect':>9s}{sep}{'bound':>8s}{sep}"
           f"{'useful':>7s}{sep}{'roofline':>8s}")
    print(hdr)
    if args.markdown:
        print("|".join(["---"] * 8))
    for arch, shape, t, cell in rows:
        print(f"{arch:24s}{sep}{shape:12s}{sep}"
              f"{fmt_s(t['compute_s']):>9s}{sep}"
              f"{fmt_s(t['memory_s']):>9s}{sep}"
              f"{fmt_s(t['collective_s']):>9s}{sep}"
              f"{t['dominant']:>8s}{sep}"
              f"{t['useful_flop_frac']:>7.3f}{sep}"
              f"{t['roofline_frac']:>8.4f}")


if __name__ == "__main__":
    main()
