"""Trace-driven architectural simulator for the NDPage reproduction.

A mechanistic (Sniper-style interval) timing model, written entirely in JAX:
set-associative caches, TLBs and page-walk caches as lax.scan state, a
queueing memory model, and the five address-translation mechanisms of the
paper (radix / ECH / huge page / NDPage / ideal) evaluated simultaneously
along a leading "mechanism" axis of every state array.
"""
from repro.sim.simulator import simulate, SimResult  # noqa: F401
