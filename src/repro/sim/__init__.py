"""Trace-driven architectural simulator for the NDPage reproduction.

A mechanistic (Sniper-style interval) timing model, written entirely in
JAX: set-associative caches, TLBs and page-walk caches as chunked
lax.scan state, a queueing memory model, and a declarative registry of
address-translation mechanisms (``repro.sim.mechanisms``) evaluated
simultaneously along a leading "mechanism" axis — the paper's five
(radix / ECH / huge page / NDPage / ideal) by default.
"""
from repro.sim.cost_model import (LookupCost, TranslationCostModel,  # noqa: F401
                                  TranslationMeter)
from repro.sim.mechanisms import (DEFAULT_MECHS, MechanismSpec,  # noqa: F401
                                  register)
from repro.sim.memory_model import (MEMORY_MODELS,  # noqa: F401
                                    MemoryModel)
from repro.sim.simulator import (MachineShape, SimJob,  # noqa: F401
                                 SimResult, machine_shape,
                                 runner_cache_info, simulate,
                                 simulate_batch, simulate_batch_varied)
from repro.sim._search import (SearchResult, SearchSpace,  # noqa: F401
                               search)
from repro.sim._sweep import (SweepResult, apply_param,  # noqa: F401
                              run_bucketed, sweep)

# This facade is the ONE public import surface of the simulator layer:
# ``from repro.sim import simulate, sweep, run_bucketed, search, ...``.
# Implementation modules are private (``_sweep`` / ``_search``); the old
# ``repro.sim.sweep`` / ``repro.sim.search`` module paths remain as thin
# shims that emit a DeprecationWarning on import (``python -m
# repro.sim.search`` still runs the CLI, warning-free).
