"""Trace-driven architectural simulator for the NDPage reproduction.

A mechanistic (Sniper-style interval) timing model, written entirely in
JAX: set-associative caches, TLBs and page-walk caches as chunked
lax.scan state, a queueing memory model, and a declarative registry of
address-translation mechanisms (``repro.sim.mechanisms``) evaluated
simultaneously along a leading "mechanism" axis — the paper's five
(radix / ECH / huge page / NDPage / ideal) by default.
"""
from repro.sim.cost_model import (LookupCost, TranslationCostModel,  # noqa: F401
                                  TranslationMeter)
from repro.sim.mechanisms import (DEFAULT_MECHS, MechanismSpec,  # noqa: F401
                                  register)
from repro.sim.simulator import (MachineShape, SimJob,  # noqa: F401
                                 SimResult, machine_shape,
                                 runner_cache_info, simulate,
                                 simulate_batch, simulate_batch_varied)
from repro.sim.sweep import SweepResult, sweep  # noqa: F401
