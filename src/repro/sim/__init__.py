"""Trace-driven architectural simulator for the NDPage reproduction.

A mechanistic (Sniper-style interval) timing model, written entirely in
JAX: set-associative caches, TLBs and page-walk caches as chunked
lax.scan state, a queueing memory model, and a declarative registry of
address-translation mechanisms (``repro.sim.mechanisms``) evaluated
simultaneously along a leading "mechanism" axis — the paper's five
(radix / ECH / huge page / NDPage / ideal) by default.
"""
from repro.sim.cost_model import (LookupCost, TranslationCostModel,  # noqa: F401
                                  TranslationMeter)
from repro.sim.mechanisms import (DEFAULT_MECHS, MechanismSpec,  # noqa: F401
                                  register)
from repro.sim.simulator import (MachineShape, SimJob,  # noqa: F401
                                 SimResult, machine_shape,
                                 runner_cache_info, simulate,
                                 simulate_batch, simulate_batch_varied)
from repro.sim.sweep import SweepResult, run_bucketed, sweep  # noqa: F401

# NOTE: the design-space search layer (repro.sim.search) is deliberately
# NOT re-exported here: it is also a ``python -m repro.sim.search`` CLI,
# and importing it from the package __init__ would make every CLI run
# warn about the module pre-existing in sys.modules.  Import it as
# ``from repro.sim.search import search, SearchSpace``.
