"""Translation cost model: simulator mechanism latencies -> serving cycles.

This is the bridge between the repo's two halves.  The timing simulator
(:mod:`repro.sim.simulator`) knows what a page walk COSTS under each
mechanism (radix / ech / hugepage / ndpage / ideal) on a given machine;
the paged-KV serving stack (:mod:`repro.serving`) knows how often the
runtime RESOLVES translations (TranslationCache hits vs misses, and how
many PTE lines a table rebuild touches under the flat vs radix block
organization).  A :class:`TranslationCostModel` carries the per-lookup
cycle costs from the first world into the second, so ``ServeEngine``
can report tokens/sec under every mechanism — the paper's end-to-end
claim (translation design changes application throughput, §VI) at the
serving layer.

Cost derivation (:meth:`TranslationCostModel.from_sim`) is ONE
simulator dispatch: all mechanisms ride the M axis of a single
:func:`repro.sim.simulate` call on the serving machine's shape, so the
whole model costs one compile per machine shape — mechanism identity is
a value-only operand, never a recompile.  Per mechanism ``m``:

  ``tlb_hit``   cycles when the serving TranslationCache hits (the
                L1-TLB analogue): the machine's L1-DTLB latency.
  ``walk``      cycles on a miss: L2-TLB probe + the simulator's
                measured average page-table-walk latency for ``m``
                (queueing, PWC hits and cache pollution included).
  ``pte_line``  cycles per ADDITIONAL PTE cache line the rebuild
                touches beyond the first: the machine's per-line DRAM
                cost (``MachineConfig.memory.line_cycles`` — under the
                banked model a contiguous-org line streams through an
                open row, a per-node line pays the closed-row total)
                for L1-bypassing mechanisms, an L1-hit-rate-weighted
                blend for cache-filling ones.
  ``org``       which serving block-table organization the mechanism's
                line count follows: flattened mechanisms count lines of
                the contiguous flat row (adjacent leaves SHARE 64B
                lines), tree mechanisms count per-node lines (each
                directory/leaf node is its own allocation — no
                sharing), ideal counts nothing.

Derived models are memoized to the trace cache (``.trace_cache/
costmodel_<key>.json`` — same directory and degrade-to-off rules as
generated traces), and :data:`PINNED_COSTS` carries a committed
fallback table for the default ``SERVING_COST`` machine so CI's fast
lane and fresh checkouts never need a simulator run (the path is
hermetic).  Bump :data:`_COST_MODEL_VERSION` whenever the derivation
changes — it is part of the memo key.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import sys
from collections import deque
from typing import Dict, Hashable, Sequence, Tuple

import numpy as np

from repro.configs.ndp_sim import (PRESETS, SERVING_COST, MachineConfig,
                                   cpu_machine, ndp_machine)
from repro.sim import mechanisms as MS
from repro.util import resilience

#: part of the memo key: bump on any change to the derivation above
_COST_MODEL_VERSION = 3

_FACTORIES = {"ndp": ndp_machine, "cpu": cpu_machine}

#: serving-table organizations a mechanism's line count can follow
ORG_FLAT = "flat"      # one contiguous row: adjacent leaves share lines
ORG_RADIX = "radix"    # per-node allocations: directory + leaf lines
ORG_NONE = "none"      # no translation structure at all (ideal)
ORG_SEG = "segment"    # range descriptors: lines ~ contiguous runs
ORG_INV = "inverted"   # hashed buckets: every entry its own line


def serving_org(name: str) -> str:
    """Which block-table organization mechanism ``name`` maps to on the
    serving side, straight from the declarative spec registry: an
    explicit ``spec.org`` override wins (the zoo's segment/inverted
    organizations); otherwise ``flattened`` mechanisms (the NDPage
    family — with or without the L1 bypass) read the single flat row,
    everything else that walks reads a tree of independently-allocated
    nodes, and ``ideal`` reads nothing."""
    spec = MS.get(name)
    if spec.org is not None:
        return spec.org
    if spec.ideal:
        return ORG_NONE
    if spec.flattened:
        return ORG_FLAT
    return ORG_RADIX


@dataclasses.dataclass(frozen=True)
class LookupCost:
    """Per-lookup cycle costs of one mechanism (see module docstring)."""

    tlb_hit: float
    walk: float
    pte_line: float
    org: str


@dataclasses.dataclass(frozen=True)
class TranslationCostModel:
    """Per-mechanism lookup costs for one serving machine.

    ``mechs`` fixes the mechanism order every vectorized result
    follows; ``source`` records how the numbers were obtained
    ("sweep" = fresh simulator run, "cache" = trace-cache memo,
    "pinned" = the committed fallback table).
    """

    mechs: Tuple[str, ...]
    costs: Tuple[LookupCost, ...]          # aligned with mechs
    machine: str
    freq_ghz: float
    model_cycles_per_token: float
    source: str

    def cost(self, mech: str) -> LookupCost:
        return self.costs[self.mechs.index(mech)]

    @functools.cached_property
    def _vectors(self) -> Tuple[np.ndarray, ...]:
        """The per-mechanism (M,) cost arrays, materialized once — the
        meter calls :meth:`lookup_cycles` on every decode step."""
        return (np.array([c.tlb_hit for c in self.costs]),
                np.array([c.walk for c in self.costs]),
                np.array([c.pte_line for c in self.costs]),
                np.array([c.org for c in self.costs]))

    @functools.cached_property
    def needs_zoo_lines(self) -> bool:
        """True when any mechanism uses the segment/inverted accounting
        — lets the meter hot path skip those counts otherwise."""
        return any(c.org in (ORG_SEG, ORG_INV) for c in self.costs)

    # -- vectorized accounting ----------------------------------------------
    def lookup_cycles(self, hit: np.ndarray, lines_flat: np.ndarray,
                      lines_radix: np.ndarray,
                      lines_seg: np.ndarray | None = None,
                      lines_inv: np.ndarray | None = None) -> np.ndarray:
        """Translation cycles for N lookups under every mechanism.

        ``hit``: (N,) bool — the serving TranslationCache hit;
        ``lines_flat``/``lines_radix`` (and, for models carrying
        segment/inverted-org mechanisms, ``lines_seg``/``lines_inv``):
        (N,) touched-PTE-line counts of the rebuilt row under each
        organization (from ``block_table.translate_all_costed`` /
        ``count_pte_lines``).  An omitted zoo count defaults to 1 line
        (no extra-line cost).  Returns (N, M) float64.
        """
        hit = np.asarray(hit, bool)[:, None]
        lf = np.asarray(lines_flat, np.float64)[:, None]
        lr = np.asarray(lines_radix, np.float64)[:, None]
        one = np.ones_like(lf)
        ls = (one if lines_seg is None
              else np.asarray(lines_seg, np.float64)[:, None])
        li = (one if lines_inv is None
              else np.asarray(lines_inv, np.float64)[:, None])
        tlb, walk, line, org = self._vectors
        lines = np.select(
            [org == ORG_FLAT, org == ORG_RADIX, org == ORG_SEG,
             org == ORG_INV],
            [lf, lr, ls, li], default=one)
        miss = walk + line * np.maximum(lines - 1.0, 0.0)
        return np.where(hit, tlb[None], miss)

    def tokens_per_sec(self, tokens: int, trans_cycles: np.ndarray,
                       model_cycles_per_token: float | None = None
                       ) -> Dict[str, float]:
        """End-to-end throughput per mechanism: the model compute budget
        (``model_cycles_per_token`` x tokens) plus each mechanism's
        accumulated translation cycles, at the machine's clock.

        ``model_cycles_per_token`` overrides the model's own value —
        the ``serving_fleet`` benchmark re-prices the SAME accumulated
        translation cycles under a grid of compute budgets to map where
        translation stops mattering, without re-running anything."""
        if tokens <= 0:
            return {m: 0.0 for m in self.mechs}
        mcpt = (self.model_cycles_per_token
                if model_cycles_per_token is None
                else float(model_cycles_per_token))
        total = mcpt * tokens + np.asarray(trans_cycles, np.float64)
        secs = total / (self.freq_ghz * 1e9)
        return {m: float(tokens / secs[i])
                for i, m in enumerate(self.mechs)}

    # -- construction -------------------------------------------------------
    @classmethod
    def from_sim(cls, mach: MachineConfig,
                 mechs: Sequence[str] | None = None, *,
                 preset: str | None = None, workload: str | None = None,
                 model_cycles_per_token: float | None = None,
                 use_cache: bool = True) -> "TranslationCostModel":
        """Derive the cost table from ONE simulator dispatch on ``mach``.

        All mechanisms are lanes of the M axis of a single
        :func:`repro.sim.simulate` call — one compile per machine
        shape, mechanism identity is value-only.  The result is
        memoized to the trace cache keyed on everything it depends on.
        """
        mechs = tuple(mechs or SERVING_COST["mechs"])
        preset = preset or SERVING_COST["preset"]
        workload = workload or SERVING_COST["workload"]
        mcpt = float(model_cycles_per_token
                     if model_cycles_per_token is not None
                     else SERVING_COST["model_cycles_per_token"])

        path = _memo_path(mach, mechs, preset, workload)
        if use_cache:
            cached = _memo_load(path, mcpt)
            if cached is not None:
                return cached

        from repro.sim.simulator import simulate
        from repro.workloads import generate_trace
        sim_preset = PRESETS[preset]
        trace = generate_trace(workload, mach.num_cores, preset=sim_preset)
        res = simulate(mach, trace, mechs=mechs, chunk=sim_preset.chunk)

        costs = []
        for m in mechs:
            spec = MS.get(m)
            if spec.ideal:
                costs.append(LookupCost(0.0, 0.0, 0.0, ORG_NONE))
                continue
            walk = (res.scalar("avg_ptw_latency", m)
                    + float(mach.l2_tlb.latency))
            org = serving_org(m)
            # contiguous orgs stream extra lines through an open DRAM
            # row under the banked model; per-node orgs pay closed rows
            # (identical to the flat latency under bounded_linear)
            dram = mach.memory.line_cycles(
                contiguous=org in (ORG_FLAT, ORG_SEG))
            if spec.bypass_l1:
                line = dram
            else:
                l1_hit = 1.0 - res.scalar("pte_l1_miss_rate", m)
                line = (l1_hit * mach.l1d.latency
                        + (1.0 - l1_hit) * dram)
            costs.append(LookupCost(
                tlb_hit=float(mach.l1_dtlb.latency), walk=round(walk, 3),
                pte_line=round(line, 3), org=org))

        model = cls(mechs=mechs, costs=tuple(costs), machine=mach.name,
                    freq_ghz=mach.freq_ghz, model_cycles_per_token=mcpt,
                    source="sweep")
        if use_cache:
            _memo_store(path, model)
        return model

    @classmethod
    def pinned(cls, model_cycles_per_token: float | None = None
               ) -> "TranslationCostModel":
        """The committed fallback table (:data:`PINNED_COSTS`) — no
        simulator run, no cache: the hermetic path for CI fast lanes
        and fresh checkouts."""
        p = PINNED_COSTS
        mcpt = float(model_cycles_per_token
                     if model_cycles_per_token is not None
                     else SERVING_COST["model_cycles_per_token"])
        return cls(
            mechs=tuple(p["mechs"]),
            costs=tuple(LookupCost(*p["costs"][m]) for m in p["mechs"]),
            machine=p["machine"], freq_ghz=p["freq_ghz"],
            model_cycles_per_token=mcpt, source="pinned")

    @classmethod
    def for_machine(cls, mach: MachineConfig | None = None, *,
                    source: str = "auto",
                    **kw) -> "TranslationCostModel":
        """The serving entry point.  ``source``:

        * ``"pinned"`` — the committed table, no simulation (hermetic);
        * ``"sweep"``  — always derive (memoized to the trace cache);
        * ``"auto"``   — derive (serving the memo when warm), falling
          back to the pinned table if the simulator path fails.
        """
        if source == "pinned":
            return cls.pinned(kw.get("model_cycles_per_token"))
        if mach is None:
            mach = _FACTORIES[SERVING_COST["machine"]](
                int(SERVING_COST["cores"]))
        if source == "sweep":
            return cls.from_sim(mach, **kw)
        if source != "auto":
            raise ValueError(f"unknown cost-model source {source!r}")
        try:
            return cls.from_sim(mach, **kw)
        except Exception as e:                      # noqa: BLE001
            print(f"# cost model: sweep derivation failed ({e!r}); "
                  "falling back to the pinned table", file=sys.stderr)
            return cls.pinned(kw.get("model_cycles_per_token"))


# ---------------------------------------------------------------------------
# trace-cache memoization (same directory + degrade rules as traces)
# ---------------------------------------------------------------------------
def _engine_digest(mechs: Tuple[str, ...]) -> str:
    """Hash of everything OUTSIDE this module the derived costs depend
    on: the full spec values of the mechanisms used (walk depth, flags,
    walk-fn identity) and the simulator / page-table / trace-generator
    sources — so a mechanism, engine, or generator change can never
    silently serve a stale memo."""
    import repro.core.page_table as _pt
    import repro.sim.memory_model as _mm
    import repro.sim.simulator as _sim
    import repro.workloads.generators as _gen
    h = hashlib.sha256()
    for s in MS.specs_for(mechs):
        h.update(repr((s.name, s.n_pte, s.parallel, s.bypass_l1,
                       s.pwc_levels, s.huge, s.flattened, s.ideal,
                       s.cache_tlb, s.segment, s.colocate, s.org,
                       getattr(s.walk_fn, "__qualname__", None))
                      ).encode())
    for mod in (_sim, _pt, _gen, MS, _mm):
        with open(mod.__file__, "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def _memo_path(mach: MachineConfig, mechs: Tuple[str, ...], preset: str,
               workload: str) -> str | None:
    from repro.workloads import trace_cache_dir
    d = trace_cache_dir()
    if d is None:
        return None
    key_src = json.dumps({
        "machine": dataclasses.asdict(mach),
        "mechs": list(mechs), "workload": workload,
        # preset VALUES, not just the name — editing PRESETS["smoke"]
        # must re-derive
        "preset": dataclasses.asdict(PRESETS[preset]),
        "engine": _engine_digest(mechs),
        "version": _COST_MODEL_VERSION,
    }, sort_keys=True, default=str)
    h = hashlib.sha256(key_src.encode()).hexdigest()[:20]
    return os.path.join(d, f"costmodel_{mach.name}_{h}.json")


def _memo_load(path: str | None, mcpt: float
               ) -> "TranslationCostModel | None":
    """Integrity-checked memo load (sha256 sidecar, quarantine on
    corruption — see :mod:`repro.util.resilience`); None = re-derive."""
    if path is None:
        return None
    p = resilience.read_json(path)
    if p is None:
        return None
    try:
        return TranslationCostModel(
            mechs=tuple(p["mechs"]),
            costs=tuple(LookupCost(*p["costs"][m]) for m in p["mechs"]),
            machine=p["machine"], freq_ghz=p["freq_ghz"],
            model_cycles_per_token=mcpt, source="cache")
    except Exception:                    # schema drift: re-derive
        resilience.quarantine(path, "costmodel memo schema mismatch")
        return None


def _memo_store(path: str | None, model: TranslationCostModel) -> None:
    if path is None:
        return
    # atomic + sidecar; filesystem failure degrades to cache-off
    resilience.write_json(path, {
        "mechs": list(model.mechs),
        "costs": {m: list(dataclasses.astuple(c))
                  for m, c in zip(model.mechs, model.costs)},
        "machine": model.machine, "freq_ghz": model.freq_ghz,
    }, indent=1)


# ---------------------------------------------------------------------------
# the committed fallback table
# ---------------------------------------------------------------------------
#: Derived once via ``TranslationCostModel.from_sim(ndp_machine(4))`` on
#: the SERVING_COST defaults (dlrm workload, smoke preset) and pinned so
#: the serving path never NEEDS a simulator run.  Regenerate with
#: ``python -m repro.sim.cost_model`` after changing the derivation or
#: the SERVING_COST preset (tests/test_cost_model.py asserts the pinned
#: and freshly-derived tables agree).
PINNED_COSTS: Dict = {
    "machine": "ndp-4c",
    "freq_ghz": 2.6,
    "mechs": ("radix", "ech", "hugepage", "ndpage", "ideal"),
    "costs": {
        # (tlb_hit, walk, pte_line, org)
        "radix": (1.0, 482.827, 90.628, ORG_RADIX),
        "ech": (1.0, 343.52, 100.0, ORG_RADIX),
        "hugepage": (1.0, 300.021, 92.463, ORG_RADIX),
        "ndpage": (1.0, 290.523, 100.0, ORG_FLAT),
        "ideal": (0.0, 0.0, 0.0, ORG_NONE),
    },
}


# ---------------------------------------------------------------------------
# the serving-side accumulator
# ---------------------------------------------------------------------------
class TranslationMeter:
    """Accumulates translation cycles per mechanism as the serving
    scheduler resolves lookups — the per-step and per-request budget
    ``ServeEngine`` reports throughput from.

    One meter serves EVERY mechanism at once: the engine runs a single
    decode loop (one compile, mechanism never enters the jit) and each
    step's cache hits / misses / touched-line counts are priced under
    all mechanisms simultaneously.
    """

    #: bounded histories, so a long-lived engine never grows without
    #: limit (running totals are exact regardless): per-step cycle
    #: vectors, and budgets of RETIRED requests.  Live requests are
    #: bounded by the scheduler's batch size.
    STEP_HISTORY = 4096
    RETIRED_HISTORY = 4096

    def __init__(self, model: TranslationCostModel,
                 max_slots: int | None = None):
        self.model = model
        m = len(model.mechs)
        self.total = np.zeros(m, np.float64)
        self.step_cycles: "deque[np.ndarray]" = deque(
            maxlen=self.STEP_HISTORY)                  # per-step (M,)
        #: live per-request budgets (seq_id -> (M,) cycles)
        self.per_request: Dict[Hashable, np.ndarray] = {}
        #: budgets of completed requests, most recent last
        self.retired: "deque[Tuple[Hashable, np.ndarray]]" = deque(
            maxlen=self.RETIRED_HISTORY)
        self.tokens = 0
        self.steps = 0
        self.hits = 0
        self.misses = 0
        # -- the vectorized slot path (fleet scheduler) ---------------------
        # per-slot live budgets as one (max_slots, M) matrix accumulated
        # array-at-once by record_slots; budgets flush into the
        # per_request / retired dicts only at release time, so NO
        # per-request Python loop runs on the step path.
        self._slot_budget = (np.zeros((max_slots, m), np.float64)
                             if max_slots else None)
        self._slot_owner: list = [None] * (max_slots or 0)

    def record_step(self, seq_ids: Sequence[Hashable], hit: np.ndarray,
                    flat_rows: np.ndarray, leaf_size: int) -> None:
        """Price one scheduler step.  ``flat_rows`` is the (N, max_pages)
        int32 mapping the step resolved (-1 holes).  Line counts are
        computed in plain numpy (no device dispatch on the decode hot
        path) and only for MISS rows — hits are priced at tlb_hit and
        never read them; tests pin the numpy path against the canonical
        ``block_table.count_pte_lines``."""
        n = len(seq_ids)
        if n == 0:
            return
        hit = np.asarray(hit, bool)
        flat = np.asarray(flat_rows, np.int32)
        lf = np.ones(n, np.int64)
        lr = np.ones(n, np.int64)
        lseg = np.ones(n, np.int64)
        linv = np.ones(n, np.int64)
        miss = np.flatnonzero(~hit)
        if miss.size:
            ls = _usable_leaf_size(flat.shape[1], leaf_size)
            lf[miss], lr[miss] = _np_row_lines(flat[miss], ls)
            if self.model.needs_zoo_lines:
                lseg[miss] = _np_seg_lines(flat[miss])
                linv[miss] = _np_inv_lines(flat[miss])
        per_seq = self.model.lookup_cycles(hit, lf, lr, lseg, linv)
        for i, sid in enumerate(seq_ids):
            if sid in self.per_request:
                self.per_request[sid] = self.per_request[sid] + per_seq[i]
            else:
                self.per_request[sid] = per_seq[i].copy()
        step = per_seq.sum(axis=0)
        self.step_cycles.append(step)
        self.total += step
        self.tokens += n                  # every active slot advances one
        self.steps += 1
        h = int(hit.sum())
        self.hits += h
        self.misses += n - h

    # -- the vectorized slot path (fleet scheduler) --------------------------
    def bind_slot(self, slot: int, req_id: Hashable) -> None:
        """Attach ``req_id`` to a scheduler slot (admission).  Requires
        the meter was built with ``max_slots``."""
        assert self._slot_budget is not None, "meter built without slots"
        assert self._slot_owner[slot] is None, (slot, req_id)
        self._slot_owner[slot] = req_id

    def record_slots(self, slots: np.ndarray, hit: np.ndarray,
                     flat_rows: np.ndarray, leaf_size: int, *,
                     shared_leaves: bool = False) -> None:
        """Vectorized :meth:`record_step` over scheduler SLOTS: prices
        one fleet step for every active slot with no per-request Python
        loop — line counts, per-mechanism cycles and the per-slot budget
        accumulation are all array-at-once.  ``shared_leaves=True``
        (prefix-sharing mixes) counts radix-org lines with batch-global
        shared-leaf dedup (:func:`_np_row_lines_shared`): a leaf walked
        by several missing sharers in the same step costs its lines
        once."""
        assert self._slot_budget is not None, "meter built without slots"
        slots = np.asarray(slots, np.int64)
        n = slots.size
        if n == 0:
            return
        hit = np.asarray(hit, bool)
        flat = np.asarray(flat_rows, np.int32)
        lf = np.ones(n, np.int64)
        lr = np.ones(n, np.int64)
        lseg = np.ones(n, np.int64)
        linv = np.ones(n, np.int64)
        miss = np.flatnonzero(~hit)
        if miss.size:
            ls = _usable_leaf_size(flat.shape[1], leaf_size)
            rows = flat[miss]
            if shared_leaves:
                lf[miss], lr[miss] = _np_row_lines_shared(rows, ls)
            else:
                lf[miss], lr[miss] = _np_row_lines(rows, ls)
            if self.model.needs_zoo_lines:
                lseg[miss] = _np_seg_lines(rows)
                linv[miss] = _np_inv_lines(rows)
        per_seq = self.model.lookup_cycles(hit, lf, lr, lseg, linv)
        self._slot_budget[slots] += per_seq      # slots are unique
        step = per_seq.sum(axis=0)
        self.step_cycles.append(step)
        self.total += step
        self.tokens += n
        self.steps += 1
        h = int(hit.sum())
        self.hits += h
        self.misses += n - h

    def release_slot(self, slot: int, *, retire: bool) -> None:
        """Fold a slot's accumulated budget into its request's dict
        entry (preemption keeps it live — re-prefill work accumulates
        across incarnations; ``retire=True`` moves it to the bounded
        retired history)."""
        assert self._slot_budget is not None, "meter built without slots"
        req_id = self._slot_owner[slot]
        assert req_id is not None, slot
        self._slot_owner[slot] = None
        budget = self._slot_budget[slot].copy()
        self._slot_budget[slot] = 0.0
        if req_id in self.per_request:
            self.per_request[req_id] = self.per_request[req_id] + budget
        else:
            self.per_request[req_id] = budget
        if retire:
            self.retire_request(req_id)

    def retire_request(self, seq_id: Hashable) -> None:
        """Move a completed request's budget out of the live dict (kept
        in the bounded ``retired`` history) — called by the scheduler
        when it frees the sequence, so the live dict stays bounded by
        the batch size."""
        budget = self.per_request.pop(seq_id, None)
        if budget is not None:
            self.retired.append((seq_id, budget))

    def request_budgets(self) -> Dict[Hashable, np.ndarray]:
        """Live AND retained-retired per-request budgets (retired
        entries beyond the history window are folded into ``total``
        only).  A recycled request id SUMS across its incarnations —
        the partition over ``total`` survives id reuse."""
        out: Dict[Hashable, np.ndarray] = {}
        live_slots = (
            [(rid, self._slot_budget[s])
             for s, rid in enumerate(self._slot_owner) if rid is not None]
            if self._slot_budget is not None else [])
        for sid, budget in (list(self.retired)
                            + list(self.per_request.items()) + live_slots):
            if sid in out:
                out[sid] = out[sid] + budget
            else:
                out[sid] = budget.copy()
        return out

    def tokens_per_sec(self, model_cycles_per_token: float | None = None
                       ) -> Dict[str, float]:
        return self.model.tokens_per_sec(self.tokens, self.total,
                                         model_cycles_per_token)

    def translation_cycles(self) -> Dict[str, float]:
        return {m: float(self.total[i])
                for i, m in enumerate(self.model.mechs)}

    def per_step_cycles(self) -> Dict[str, Dict[str, float]]:
        """The per-step translation budget over the retained step
        window: mean and worst-case (miss-heavy) step cycles per
        mechanism."""
        if not self.step_cycles:
            return {m: {"mean": 0.0, "max": 0.0}
                    for m in self.model.mechs}
        steps = np.stack(self.step_cycles)            # (S, M)
        return {m: {"mean": float(steps[:, i].mean()),
                    "max": float(steps[:, i].max())}
                for i, m in enumerate(self.model.mechs)}


def _usable_leaf_size(max_pages: int, leaf_size: int) -> int:
    """Largest leaf size <= requested that divides ``max_pages`` (the
    radix builder requires an exact split)."""
    ls = max(1, min(leaf_size, max_pages))
    while max_pages % ls:
        ls -= 1
    return ls


def _np_group_lines(mapped: np.ndarray) -> np.ndarray:
    """Numpy twin of ``block_table._lines_of`` (same PTE_PER_LINE
    granularity, pinned equal by tests): touched line groups of a
    line-aligned span, over the last axis."""
    from repro.core.block_table import PTE_PER_LINE
    n = mapped.shape[-1]
    pad = (-n) % PTE_PER_LINE
    m = np.pad(mapped, [(0, 0)] * (mapped.ndim - 1) + [(0, pad)])
    groups = m.reshape(m.shape[:-1] + (-1, PTE_PER_LINE))
    return groups.any(-1).sum(-1)


def _np_row_lines(flat: np.ndarray, leaf_size: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Touched-PTE-line counts of (N, max_pages) mapping rows under the
    flat and the radix organization, pure numpy — the decode-hot-path
    equivalent of ``count_pte_lines(flat/radix_from_flat(flat))`` for
    the unique-leaf tables the scheduler builds."""
    mapped = flat >= 0                                # (N, maxp)
    lf = _np_group_lines(mapped)
    n, maxp = mapped.shape
    leaves = mapped.reshape(n, maxp // leaf_size, leaf_size)
    dir_valid = leaves.any(-1)                        # (N, n_dir)
    lr = _np_group_lines(dir_valid) + _np_group_lines(leaves).sum(-1)
    return lf, lr


def _np_row_lines_shared(flat: np.ndarray, leaf_size: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """:func:`_np_row_lines` with BATCH-GLOBAL shared-leaf dedup on the
    radix count — the numpy hot-path twin of
    ``block_table.count_pte_lines_shared`` (pinned equal by tests).

    A leaf whose physical-page content is identical across rows (a
    prefix-shared system prompt) is one allocation: its lines are
    charged to the FIRST row (row-major) referencing it and zero to
    every other sharer.  The flat count is unchanged — each flat row is
    its own contiguous allocation, so prefix sharing buys it nothing
    (NDPage's tradeoff, surfaced end-to-end).
    """
    mapped = flat >= 0                                # (N, maxp)
    lf = _np_group_lines(mapped)
    n, maxp = mapped.shape
    n_dir = maxp // leaf_size
    leaves = flat.reshape(n * n_dir, leaf_size)
    lmapped = mapped.reshape(n * n_dir, leaf_size)
    valid = lmapped.any(-1)
    lines = np.zeros(n * n_dir, np.int64)
    vidx = np.flatnonzero(valid)
    if vidx.size:
        sub = leaves[vidx]
        # deterministic first occurrence of each distinct leaf content
        # (np.unique's return_index is not guaranteed first-occurrence
        # for axis-based unique)
        _, inverse = np.unique(sub, axis=0, return_inverse=True)
        inverse = inverse.reshape(-1)
        first = np.full(int(inverse.max()) + 1, vidx.size, np.int64)
        np.minimum.at(first, inverse, np.arange(vidx.size))
        keep = vidx[first]
        lines[keep] = _np_group_lines(lmapped[keep])
    dir_valid = valid.reshape(n, n_dir)
    lr = _np_group_lines(dir_valid) + lines.reshape(n, n_dir).sum(-1)
    return lf, lr


def _np_seg_lines(flat: np.ndarray) -> np.ndarray:
    """Numpy twin of ``block_table.count_segment_lines`` (pinned equal
    by tests): descriptor lines for the SEGMENT org — one range per
    maximal physically-contiguous mapped run, RANGES_PER_LINE per
    line."""
    from repro.core.block_table import RANGES_PER_LINE
    flat = np.asarray(flat, np.int64)
    mapped = flat >= 0
    nd = flat.ndim
    pad_cfg = [(0, 0)] * (nd - 1) + [(1, 0)]
    prev_m = np.pad(mapped[..., :-1], pad_cfg, constant_values=False)
    prev_p = np.pad(flat[..., :-1], pad_cfg, constant_values=-2)
    runs = (mapped & (~prev_m | (flat != prev_p + 1))).sum(-1)
    return (runs + RANGES_PER_LINE - 1) // RANGES_PER_LINE


def _np_inv_lines(flat: np.ndarray) -> np.ndarray:
    """Numpy twin of ``block_table.count_inverted_lines``: every mapped
    entry hashes to its own bucket line — no sharing, ever."""
    return (np.asarray(flat, np.int64) >= 0).sum(-1)


def _main() -> int:                     # pragma: no cover - dev utility
    """Regenerate :data:`PINNED_COSTS` from the SERVING_COST defaults."""
    mach = _FACTORIES[SERVING_COST["machine"]](int(SERVING_COST["cores"]))
    model = TranslationCostModel.from_sim(mach, use_cache=False)
    print(json.dumps({
        "machine": model.machine, "freq_ghz": model.freq_ghz,
        "mechs": model.mechs,
        "costs": {m: dataclasses.astuple(c)
                  for m, c in zip(model.mechs, model.costs)},
    }, indent=1, default=str))
    return 0


if __name__ == "__main__":              # pragma: no cover
    sys.exit(_main())
