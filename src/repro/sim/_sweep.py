"""Declarative machine-parameter sweep engine for sensitivity studies.

The paper's headline numbers rest on sensitivity analyses — PWC/TLB
sizing, L1-bypass on/off, flattened-level choice, core scaling — that
used to mean hand-editing ``MachineConfig`` and paying one compile per
variant.  :func:`sweep` takes a declarative grid over machine
parameters × mechanisms × workloads, buckets the cross-product by
*compiled shape* (``machine_shape`` + mechanism walk-fn tuple), and
runs each bucket as ONE batched chunked-scan dispatch via
:func:`repro.sim.simulator.simulate_batch_varied`.  Parameter values
that don't change array shapes — latencies, memory service time,
bypass/PWC/huge flags, walk depth — ride the batch lanes as data, so
e.g. a 4-latency × 6-workload grid is 24 simulations, one bucket, one
compile.

Grid axes (an ordered mapping ``name -> values``):

  ``workload``    Table-II workload names, or ``"trace:<path>"`` for
                  ingested real traces (see repro.workloads.ingest)
  ``machine``     "ndp" | "cpu" (Table-I machine family)
  ``cores``       core count (passed to the machine factory)
  ``mechs``       mechanism-name tuples from the spec registry
  anything else   a ``MachineConfig`` override path, dotted for nested
                  fields: "pwc_entries", "l1_dtlb.entries",
                  "l2_tlb.entries", "l1d.size_bytes", "memory.latency",
                  "memory.t_cas" — plus "memory_model", which switches
                  to a named MemoryModel preset (calibration-preserving)

Named presets for the paper's sensitivity figures live in
``repro.configs.ndp_sim.SWEEPS`` (plain data, consumed here) and run as
``sweep("pwc_size")``; ``benchmarks/sim_sweep.py`` drives them all and
records per-bucket compile counts.

:class:`SweepResult` keeps the named axes: ``select(axis=value)`` drops
an axis, ``select(axis=[...])`` subsets it, ``scalar(metric, mech)`` /
``speedup(mech)`` evaluate a derived metric over the whole grid as a
plain ndarray, and ``point(...)`` returns one ``SimResult``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import itertools
import json
import os
import time
from collections import OrderedDict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.ndp_sim import (PRESETS, SWEEPS, MachineConfig,
                                   cpu_machine, ndp_machine)
from repro.sim.mechanisms import DEFAULT_MECHS, get as _get_mech
from repro.sim.simulator import (SimJob, SimResult, clear_runner_cache,
                                 machine_shape, runner_cache_info,
                                 simulate_batch_varied, _walk_fns)
from repro.util import resilience

#: axis names with dedicated semantics; everything else is a
#: MachineConfig override path
SPECIAL_AXES = ("workload", "machine", "cores", "mechs")

_FACTORIES = {"ndp": ndp_machine, "cpu": cpu_machine}


# ---------------------------------------------------------------------------
# grid -> points
# ---------------------------------------------------------------------------
def _field_names(obj) -> set:
    return {f.name for f in dataclasses.fields(obj)}


def apply_param(mach: MachineConfig, path: str, value) -> MachineConfig:
    """Non-destructively override one MachineConfig field; one level of
    dotting reaches into the nested Cache/TLB/MemoryModel params
    ("l1_dtlb.entries", "l1d.size_bytes", "memory.t_cas").  Validates
    against dataclass FIELDS, so derived properties (e.g.
    ``l1d.num_sets``) are rejected with a named error rather than
    crashing in ``dataclasses.replace``.

    Two memory-specific paths get dedicated semantics: ``memory_model``
    switches the machine to a named :data:`~repro.sim.memory_model.
    MEMORY_MODELS` preset while keeping its calibration (see
    :func:`~repro.sim.memory_model.with_kind`), and unknown
    ``memory.*`` knobs raise a ``ValueError`` that LISTS the knobs (a
    typo'd override must never silently no-op a whole sweep).  The
    legacy flat paths ``mem_latency``/``mem_bandwidth_gbs``/
    ``mem_service`` are rewritten to their ``memory.*`` equivalents
    with the one-per-process DeprecationWarning."""
    from repro.sim import memory_model as _mm
    if path == "memory_model":
        return dataclasses.replace(mach,
                                   memory=_mm.with_kind(mach.memory, value))
    if path in _mm.LEGACY_FIELDS:
        _mm.warn_legacy_memory(f"sweep/search path {path!r}")
        path = f"memory.{_mm.LEGACY_FIELDS[path]}"
    head, _, rest = path.partition(".")
    if head == "memory" and rest and rest not in _field_names(
            _mm.MemoryModel):
        knobs = ", ".join(f"memory.{f.name}"
                          for f in dataclasses.fields(_mm.MemoryModel))
        raise ValueError(
            f"unknown memory-model knob {path!r}: known knobs are "
            f"{knobs}, or 'memory_model' to switch presets "
            f"{tuple(_mm.MEMORY_MODELS)}")
    if head not in _field_names(mach):
        raise KeyError(
            f"unknown sweep parameter {path!r}: MachineConfig has no "
            f"field {head!r}")
    if rest:
        sub = getattr(mach, head)
        if (sub is None or not dataclasses.is_dataclass(sub)
                or rest not in _field_names(sub)):
            raise KeyError(
                f"unknown sweep parameter {path!r}: "
                f"{type(sub).__name__ if sub is not None else None} has "
                f"no field {rest!r}")
        return dataclasses.replace(
            mach, **{head: dataclasses.replace(sub, **{rest: value})})
    return dataclasses.replace(mach, **{head: value})


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One fully-resolved grid point."""

    mach: MachineConfig
    workload: str
    mechs: Tuple[str, ...]


def _resolve_point(named: Dict, base: str, cores: int, workload: str,
                   mechs: Tuple[str, ...]) -> SweepPoint:
    named = dict(named)
    family = named.pop("machine", base)
    if family not in _FACTORIES:
        raise KeyError(f"unknown machine family {family!r}; "
                       f"known: {sorted(_FACTORIES)}")
    mach = _FACTORIES[family](int(named.pop("cores", cores)))
    w = named.pop("workload", workload)
    # "trace:<path>" values ingest a real trace (repro.workloads.ingest)
    # instead of naming a Table-II generator; either way the ONE spec
    # parser validates (unknown names / bad trace options fail HERE,
    # not deep inside a bucketed run)
    from repro.workloads import parse_workload_spec
    parse_workload_spec(str(w))
    mnames = tuple(named.pop("mechs", mechs))
    for n in mnames:
        _get_mech(n)                      # fail fast on unknown mechanisms
    for path, value in named.items():
        mach = apply_param(mach, path, value)
    return SweepPoint(mach=mach, workload=w, mechs=mnames)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SweepResult:
    """Grid of :class:`SimResult` with named axes.

    ``axes`` maps axis name -> value tuple in grid order; ``results``
    is an object ndarray of the same shape; ``stats`` records the
    bucketing/compile accounting of the run.
    """

    axes: "OrderedDict[str, Tuple]"
    results: np.ndarray
    stats: Dict

    def axis(self, name: str) -> Tuple:
        return self.axes[name]

    def _index(self, name: str, v) -> int:
        vals = list(self.axes[name])
        try:
            return vals.index(v)
        except ValueError:
            raise KeyError(f"axis {name!r} has no value {v!r}; "
                           f"values: {vals}") from None

    def select(self, **kw) -> "SweepResult":
        """Slice by axis name: a single axis value drops the axis, a
        list/tuple of values keeps it restricted to those values (order
        as given).  A tuple that IS one of the axis's values (e.g. a
        mechanism tuple on a ``mechs`` axis) selects that single value.
        Unknown axis names raise."""
        unknown = set(kw) - set(self.axes)
        if unknown:
            raise KeyError(f"unknown sweep axes {sorted(unknown)}; "
                           f"have {list(self.axes)}")
        out = self.results
        axes = OrderedDict()
        drop = []
        for dim, (name, vals) in enumerate(self.axes.items()):
            if name not in kw:
                axes[name] = vals
                continue
            sel = kw[name]
            if not isinstance(sel, np.ndarray) and sel in vals:
                # one axis value: drop the axis

                out = np.take(out, [self._index(name, sel)], axis=dim)
                drop.append(dim)
            elif isinstance(sel, (list, tuple, np.ndarray)):
                out = np.take(out, [self._index(name, v) for v in sel],
                              axis=dim)
                axes[name] = tuple(sel)
            else:
                self._index(name, sel)               # raises with values
        if drop:
            out = np.squeeze(out, axis=tuple(drop))
        return SweepResult(axes=axes, results=out, stats=self.stats)

    def point(self, **kw) -> SimResult:
        """The single :class:`SimResult` at one fully-specified grid
        point (every remaining axis must resolve to one value)."""
        r = self.select(**kw)
        if r.results.size != 1:
            raise KeyError(f"point() needs every axis pinned; still "
                           f"open: {dict(r.axes)}")
        return r.results.reshape(())[()]

    def map(self, fn) -> np.ndarray:
        """Apply ``fn(SimResult) -> float`` over the grid."""
        out = np.empty(self.results.shape, np.float64)
        for idx in np.ndindex(*self.results.shape):
            out[idx] = fn(self.results[idx])
        return out

    def scalar(self, metric: str, mech: str) -> np.ndarray:
        """``SimResult.scalar(metric, mech)`` over the whole grid."""
        return self.map(lambda r: r.scalar(metric, mech))

    def speedup(self, mech: str, base: str = "radix") -> np.ndarray:
        """Mean-cycle speedup of ``mech`` vs ``base`` over the grid."""
        return self.map(lambda r: r.speedup_vs(base)[mech])


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
#: SimResult array fields, in (de)serialization order, for checkpoints
_RESULT_FIELDS = ("cycles", "instructions", "trans_cycles", "walk_cycles",
                  "walks", "l1tlb_misses", "pte_accesses", "pte_l1_hits",
                  "pte_mem", "data_l1_misses", "data_mem")


@functools.lru_cache(maxsize=1)
def _engine_ckpt_digest() -> str:
    """Hash of every source the checkpointed results depend on besides
    the jobs themselves — a code change can never serve stale bucket
    results."""
    import repro.core.page_table as _pt
    import repro.sim.mechanisms as _mech
    import repro.sim.memory_model as _mm
    import repro.sim.simulator as _sim
    import repro.workloads.generators as _gen
    from repro.configs import ndp_sim as _cfg
    h = hashlib.sha256()
    for mod in (_sim, _mech, _mm, _gen, _pt, _cfg):
        with open(mod.__file__, "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def checkpoint_key(jobs: Sequence[SimJob], chunk: int,
                   length: int | None) -> str:
    """Content key of one ``run_bucketed`` call: engine sources, chunk
    layout, and every job's machine, mechanisms and trace BYTES (str
    trace specs hash the underlying file) — the same staleness
    discipline as the trace cache."""
    h = hashlib.sha256()
    h.update(_engine_ckpt_digest().encode())
    h.update(json.dumps({"chunk": chunk, "length": length}).encode())
    memo: Dict[int, str] = {}
    for j in jobs:
        h.update(json.dumps(dataclasses.asdict(j.mach), sort_keys=True,
                            default=str).encode())
        h.update(repr(tuple(j.mechs)).encode())
        t = j.trace
        if isinstance(t, str):
            h.update(t.encode())
            if t.startswith("trace:"):
                from repro.workloads.ingest import (file_sha256,
                                                    parse_trace_spec)
                h.update(file_sha256(parse_trace_spec(t)[0]).encode())
        else:
            tid = id(t)
            if tid not in memo:
                th = hashlib.sha256()
                for k in ("vpn", "off", "work"):
                    th.update(np.ascontiguousarray(t[k]).tobytes())
                th.update(str(int(t["pages"])).encode())
                memo[tid] = th.hexdigest()
            h.update(memo[tid].encode())
    return h.hexdigest()[:20]


def _ckpt_pack(results: Sequence[SimResult]) -> Dict:
    out: Dict = {"n": np.int64(len(results))}
    for k, r in enumerate(results):
        out[f"j{k}_mechs"] = np.asarray(r.mechs)
        out[f"j{k}_accesses"] = np.int64(r.accesses)
        for f in _RESULT_FIELDS:
            out[f"j{k}_{f}"] = getattr(r, f)
    return out


def _ckpt_unpack(arrays: Dict, expect: int) -> Optional[List[SimResult]]:
    try:
        if int(arrays["n"]) != expect:
            return None
        return [SimResult(
            mechs=tuple(str(m) for m in arrays[f"j{k}_mechs"]),
            accesses=int(arrays[f"j{k}_accesses"]),
            **{f: arrays[f"j{k}_{f}"] for f in _RESULT_FIELDS})
            for k in range(expect)]
    except KeyError:                     # schema drift: re-dispatch
        return None


def _resolve_checkpoint(checkpoint, jobs, chunk, length
                        ) -> Optional[str]:
    """The checkpoint path prefix for this call, or None (off).

    ``checkpoint``: None consults ``SIM_SWEEP_CHECKPOINT`` (unset/0 =
    off, any other value = on); True/"auto" derive the content key;
    any other string IS the key (caller-managed staleness)."""
    if checkpoint is None:
        env = os.environ.get("SIM_SWEEP_CHECKPOINT", "")
        checkpoint = env not in ("", "0") and (env
                                               if env != "1" else "auto")
    if not checkpoint:
        return None
    from repro.workloads import trace_cache_dir
    d = trace_cache_dir()
    if d is None:
        return None
    key = (checkpoint_key(jobs, chunk, length)
           if checkpoint in (True, "auto")
           else str(checkpoint))
    return os.path.join(d, f"sweepckpt_{key}")


def run_bucketed(jobs: Sequence[SimJob], *, chunk: int,
                 devices: int | None = None,
                 length: int | None = None,
                 checkpoint: "bool | str | None" = None,
                 watchdog_s: float | None = None
                 ) -> Tuple[List[SimResult], Dict]:
    """The sweep engine's dispatch core, reusable on any heterogeneous
    job list (the design-space search feeds whole candidate populations
    through here): bucket ``jobs`` by compiled shape — ``machine_shape``
    x the mechanisms' walk-fn tuple — and run each bucket as ONE
    :func:`simulate_batch_varied` dispatch.  Value-only differences
    (latencies, bypass/PWC/huge flags, walk depth) ride the batch lanes,
    so compile count is bounded by the number of buckets, never the
    number of jobs.

    Resilience (both off by default; benchmarks and the nightly enable
    them):

    * ``checkpoint`` — persist each completed bucket's results to
      ``.trace_cache/sweepckpt_<key>_b<i>.npz`` (integrity-checked,
      atomic; key covers engine sources + every job's machine/mechs/
      trace bytes).  A killed run resumed with the same jobs loads the
      finished buckets bit-exactly and dispatches ONLY the rest —
      resumed buckets cost zero compiles (``runner_cache_info``-
      visible).  ``True``/"auto" derives the key; a string is used as
      the key verbatim; None consults ``SIM_SWEEP_CHECKPOINT``.
    * ``watchdog_s`` — wall-clock deadline per bucket dispatch; a hung
      dispatch (or an injected ``dispatch`` fault) gets ONE retry
      after :func:`repro.sim.simulator.clear_runner_cache`.  None
      consults ``SIM_DISPATCH_TIMEOUT`` (seconds; 0 = no deadline,
      injected faults still exercise the retry path).

    Returns the per-job :class:`SimResult` list (job order preserved)
    plus the bucketing/compile stats dict ``sweep()`` exposes as
    ``SweepResult.stats`` (minus the grid-level entries)."""
    if watchdog_s is None:
        watchdog_s = float(os.environ.get("SIM_DISPATCH_TIMEOUT", "0")
                           or 0)
    ckpt_prefix = _resolve_checkpoint(checkpoint, jobs, chunk, length)

    buckets: "OrderedDict[Tuple, List[int]]" = OrderedDict()
    for i, j in enumerate(jobs):
        key = (machine_shape(j.mach), _walk_fns(j.mechs))
        buckets.setdefault(key, []).append(i)

    results: List[SimResult] = [None] * len(jobs)   # type: ignore[list-item]
    info0 = runner_cache_info()
    per_bucket = []
    resumed_buckets = 0
    t0 = time.perf_counter()
    for bi, ((shape, wf), idxs) in enumerate(buckets.items()):
        # the display key must be as discriminating as the bucket key:
        # the memory shape (bank geometry) is part of machine_shape, so
        # two banked/bounded buckets must never print identically
        shape_str = (f"{shape.num_cores}c/"
                     + ":".join(str(p) for p in shape.memory) + "/"
                     + ",".join(f"{n}:{s}x{w}" for n, s, w in shape.tables))
        entry = {
            "shape": shape_str,
            "walk_fns": [getattr(f, "__qualname__", str(f)) if f else None
                         for f in wf],
            "points": list(idxs),
            "lanes": len(idxs),
        }
        ckpt_path = (f"{ckpt_prefix}_b{bi:03d}.npz"
                     if ckpt_prefix else None)
        outs = None
        if ckpt_path is not None:
            arrays = resilience.read_npz(ckpt_path)
            if arrays is not None:
                outs = _ckpt_unpack(arrays, len(idxs))
        if outs is not None:
            resumed_buckets += 1
            resilience.log_event(
                "resume", f"bucket {bi} ({shape_str}, {len(idxs)} lanes) "
                          f"restored from {os.path.basename(ckpt_path)}")
            entry.update(compiles=0, total_s=0.0, compile_s_est=0.0,
                         resumed=True)
        else:
            before = runner_cache_info().misses
            tm: Dict = {}
            tag = f"bucket{bi}:{shape_str}"

            def _dispatch():
                inj = resilience.fault_injector()
                if inj is not None and inj.fires("dispatch", tag):
                    raise resilience.DispatchTimeout(
                        f"injected dispatch fault: {tag}")
                return simulate_batch_varied(
                    [jobs[i] for i in idxs], length, chunk=chunk,
                    devices=devices, timings=tm)

            outs = resilience.watchdog_call(
                _dispatch, watchdog_s, tag=tag, retries=1,
                on_timeout=clear_runner_cache)
            entry.update(
                compiles=runner_cache_info().misses - before,
                total_s=round(tm.get("total_s", 0.0), 3),
                compile_s_est=round(tm.get("compile_s_est", 0.0), 3),
                resumed=False)
            if ckpt_path is not None:
                resilience.write_npz(ckpt_path, _ckpt_pack(outs))
        for i, res in zip(idxs, outs):
            results[i] = res
        per_bucket.append(entry)
    return results, {
        "points": len(jobs),
        "buckets": len(buckets),
        # buckets may split one machine shape across walk-fn tuples, so
        # count the shapes themselves for the compile accounting
        "distinct_shapes": len({shape for shape, _ in buckets}),
        "runner_compiles": runner_cache_info().misses - info0.misses,
        "resumed_buckets": resumed_buckets,
        "wall_s": round(time.perf_counter() - t0, 3),
        "chunk": chunk,
        "per_bucket": per_bucket,
    }


GridLike = Union[str, Mapping[str, Sequence], "OrderedDict[str, Tuple]"]


def named_sweep(name: str) -> Dict:
    """The declarative preset dict from ``configs.ndp_sim.SWEEPS``."""
    try:
        return dict(SWEEPS[name])
    except KeyError:
        raise KeyError(f"unknown sweep preset {name!r}; "
                       f"available: {sorted(SWEEPS)}") from None


#: fallbacks when neither the call nor a preset pins a knob
_DEFAULTS = dict(base="ndp", cores=4, workload="rnd",
                 mechs=DEFAULT_MECHS, preset="smoke")


def sweep(grid: GridLike, *, base: str | None = None,
          cores: int | None = None, workload: str | None = None,
          mechs: Tuple[str, ...] | None = None,
          preset: str | None = None, trace_len: int | None = None,
          seed: int | None = None, chunk: int | None = None,
          devices: int | None = None,
          checkpoint: "bool | str | None" = None,
          watchdog_s: float | None = None) -> SweepResult:
    """Run a sensitivity grid, one batched dispatch per shape bucket.

    ``grid`` is an ordered ``axis -> values`` mapping (see module
    docstring) or the name of a preset in ``configs.ndp_sim.SWEEPS``
    (whose entry may also carry ``base``/``cores``/``workload``/
    ``mechs``/``preset`` defaults; explicit keyword arguments win over
    the preset, which wins over the module defaults).  ``preset`` names
    a ``SimPreset`` supplying trace length / seed / chunk (default
    "smoke"); explicit ``trace_len``/``seed``/``chunk`` win.
    """
    kw = dict(base=base, cores=cores, workload=workload,
              mechs=mechs, preset=preset)
    if isinstance(grid, str):
        spec = named_sweep(grid)
        axes_src = spec.pop("axes")
        spec.pop("figure", None)          # human-facing, not a parameter
        for k, v in spec.items():
            if k not in kw:
                raise KeyError(f"sweep preset {grid!r}: unknown key {k!r}")
            if kw[k] is None:
                kw[k] = v
    else:
        axes_src = grid.items() if isinstance(grid, Mapping) else grid
    for k, v in _DEFAULTS.items():
        if kw[k] is None:
            kw[k] = v

    sim_preset = PRESETS[kw["preset"]]
    trace_len = sim_preset.trace_len if trace_len is None else trace_len
    seed = sim_preset.seed if seed is None else seed
    chunk = sim_preset.chunk if chunk is None else chunk

    axes: "OrderedDict[str, Tuple]" = OrderedDict(
        (name, tuple(vals)) for name, vals in axes_src)
    if not axes:
        raise ValueError("sweep needs at least one axis")
    for name, vals in axes.items():
        if not vals:
            raise ValueError(f"sweep axis {name!r} has no values")

    dims = tuple(len(v) for v in axes.values())
    points: List[SweepPoint] = []
    for combo in itertools.product(*axes.values()):
        points.append(_resolve_point(
            dict(zip(axes, combo)), kw["base"], kw["cores"],
            kw["workload"], kw["mechs"]))

    # resolve each point's trace once per (workload, cores), then hand
    # the whole cross-product to the bucketed dispatch core: one
    # simulate_batch_varied call per (machine shape, walk-fn) bucket,
    # value-only differences riding the lanes
    from repro.workloads import generate_trace
    traces: Dict[Tuple[str, int], Dict] = {}   # (workload, cores) -> trace
    for p in points:
        key = (p.workload, p.mach.num_cores)
        if key not in traces:
            traces[key] = generate_trace(key[0], key[1], length=trace_len,
                                         seed=seed, preset=sim_preset)
    jobs = [SimJob(p.mach, traces[p.workload, p.mach.num_cores], p.mechs)
            for p in points]
    outs, stats = run_bucketed(jobs, chunk=chunk, devices=devices,
                               checkpoint=checkpoint,
                               watchdog_s=watchdog_s)
    results = np.empty(dims, object)
    for i, res in enumerate(outs):
        results[np.unravel_index(i, dims)] = res
    stats["trace_len"] = trace_len
    return SweepResult(axes=axes, results=results, stats=stats)
