"""Declarative mechanism specs for the NDP translation simulator.

Every address-translation mechanism the simulator can evaluate is ONE
:class:`MechanismSpec` describing its static structure:

  * how many PTE accesses a page walk performs and whether they issue
    serially (radix-style pointer chase) or in parallel (ECH probes),
  * whether PTE fills go through the cache hierarchy (polluting it) or
    bypass straight to memory (NDPage),
  * which walk levels have a page-walk cache in front of them,
  * whether the mechanism maps 2MB huge pages (enabling the TLB-reach
    scaling + fragmentation/promotion-stall model), and
  * the function mapping a VPN to the PTE cache-line ids its walk touches
    (from :mod:`repro.core.page_table`).

``simulator.py``, ``cache_model.py`` callers, ``configs/ndp_sim.py``,
``benchmarks/sim_figures.py`` and the tests all consume the one registry
below; adding a mechanism is a single ``register(MechanismSpec(...))`` —
see ``ndpage_pl3`` at the bottom for a worked example (a flattened-PL3
NDPage variant that merges L3/L2/L1 into one giant node).

The registry is intentionally NOT auto-simulated: :data:`DEFAULT_MECHS`
pins the paper's five mechanisms so figure reproductions stay stable;
``simulate(..., mechs=(...))`` opts into any registered subset/ordering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import page_table as PT

# Upper bound on PTE accesses per walk across all registered mechanisms;
# walk-line arrays are padded to this width.
MAX_PTE = 4


@dataclasses.dataclass(frozen=True)
class MechanismSpec:
    """Static structure of one address-translation mechanism."""

    name: str
    #: PTE accesses per walk (0 = no translation at all, i.e. ideal)
    n_pte: int
    #: probes issue simultaneously; walk latency is max() of the probes
    #: plus a fixed issue/conflict overhead (ECH cuckoo probing)
    parallel: bool = False
    #: PTE accesses skip the cache hierarchy and go straight to memory
    #: (NDPage observation A: PTEs cannot live in the tiny NDP L1 anyway)
    bypass_l1: bool = False
    #: page-walk cache present per walk level (index 0 = top level)
    pwc_levels: Tuple[bool, ...] = (False,) * MAX_PTE
    #: 2MB mappings: scaled TLB keys, 4KB-fallback fragmentation model and
    #: amortized promotion/fault stall
    huge: bool = False
    #: the walk's bottom reads ONE flattened (merged) node — NDPage's
    #: design point.  Consumed by the serving cost model: flattened
    #: mechanisms price table rebuilds with the contiguous flat-row
    #: line counts (adjacent leaves share cache lines), tree mechanisms
    #: with per-node counts.
    flattened: bool = False
    #: translation is free (no TLB, no walk) — the paper's upper bound
    ideal: bool = False
    #: probes a cache-as-TLB level (Victima): on a machine with
    #: ``ctlb_kb > 0`` the mechanism checks the repurposed-cache TLB
    #: after an L2-TLB miss before walking; ignored when the machine
    #: has no ctlb (degrades exactly to the underlying walk)
    cache_tlb: bool = False
    #: direct-segment fast path (Picorel): accesses inside the
    #: contiguous segment (the non-fragmented share of the footprint)
    #: translate by base/limit registers — no TLB lookup, no walk; only
    #: the fragmentation-broken remainder takes the walk below
    segment: bool = False
    #: co-location-aware vpn->frame placement (CODA): on a machine with
    #: ``num_stacks > 1`` this mechanism's memory accesses mostly land
    #: in the LOCAL stack and dodge the remote-stack hop penalty
    colocate: bool = False
    #: serving cost-model organization override ("segment"/"inverted");
    #: None derives flat/radix/none from flattened/ideal as before
    org: Optional[str] = None
    #: VPN -> (T, n_pte) PTE line ids; None only when n_pte == 0
    walk_fn: Optional[Callable] = None
    description: str = ""

    def __post_init__(self):
        if not 0 <= self.n_pte <= MAX_PTE:
            raise ValueError(f"{self.name}: n_pte must be in [0, {MAX_PTE}]")
        if len(self.pwc_levels) != MAX_PTE:
            raise ValueError(f"{self.name}: pwc_levels must have {MAX_PTE} "
                             "entries (pad with False)")
        if self.n_pte > 0 and self.walk_fn is None:
            raise ValueError(f"{self.name}: walking mechanisms need walk_fn")
        if any(self.pwc_levels[self.n_pte:]):
            raise ValueError(f"{self.name}: PWC beyond walk depth")
        if self.huge and self.segment:
            raise ValueError(f"{self.name}: huge and segment both claim "
                             "the fragmentation mask — pick one")
        if self.org not in (None, "flat", "radix", "segment", "inverted",
                            "none"):
            raise ValueError(f"{self.name}: unknown org {self.org!r}")


@dataclasses.dataclass(frozen=True)
class MechTables:
    """The spec registry lowered to numpy tables with a leading M axis —
    what the jitted simulator step actually closes over."""

    names: Tuple[str, ...]
    n_pte: np.ndarray        # (M,)   int32
    parallel: np.ndarray     # (M,)   bool
    bypass: np.ndarray       # (M,)   bool
    pwc_on: np.ndarray       # (M, MAX_PTE) bool
    huge: np.ndarray         # (M,)   bool
    ideal: np.ndarray        # (M,)   bool
    cache_tlb: np.ndarray    # (M,)   bool
    segment: np.ndarray      # (M,)   bool
    colocate: np.ndarray     # (M,)   bool

    @property
    def num_mechs(self) -> int:
        return len(self.names)


_REGISTRY: Dict[str, MechanismSpec] = {}
#: callbacks run on every (re-)registration — the simulator hooks its
#: compiled-runner cache in here so overwritten specs can't serve stale jits
_INVALIDATE_HOOKS = []


def on_register(hook) -> None:
    _INVALIDATE_HOOKS.append(hook)


def _validate_walk_fn(spec: MechanismSpec) -> None:
    """Registration-time walk-fn/flag consistency checks.

    Two latent hazards guarded here:

    * a walk fn whose output width disagrees with ``n_pte`` would be
      silently padded/truncated by the engine — probe it on a tiny vpn
      array and reject the mismatch loudly;
    * sweep bucketing, per-bucket stats and every engine digest identify
      walk fns by ``__qualname__``.  Sharing one walk *function object*
      across specs is a feature (one compiled bucket — ndpage /
      ndpage_nobyp), but a DIFFERENT function that merely shares the
      qualname (two lambdas, same-named fns from different modules)
      would silently collide in bucketing and cache keys — reject it.
    """
    if spec.walk_fn is None:
        return
    qn = getattr(spec.walk_fn, "__qualname__", repr(spec.walk_fn))
    for other in _REGISTRY.values():
        if other.name == spec.name or other.walk_fn is None:
            continue
        oqn = getattr(other.walk_fn, "__qualname__", repr(other.walk_fn))
        if other.walk_fn is not spec.walk_fn and oqn == qn:
            raise ValueError(
                f"{spec.name}: walk_fn __qualname__ {qn!r} collides with "
                f"mechanism {other.name!r}'s distinct walk fn — sweep "
                "bucketing and cache digests key on qualnames; rename "
                "the function (or share the same function object)")
    probe = np.asarray(spec.walk_fn(np.zeros(2, np.int32)))
    if probe.shape != (2, spec.n_pte):
        raise ValueError(
            f"{spec.name}: walk_fn returns shape {probe.shape} for a "
            f"(2,) vpn array but n_pte={spec.n_pte} expects "
            f"(2, {spec.n_pte}) — the engine would silently "
            "pad/truncate the walk")


def register(spec: MechanismSpec, *, overwrite: bool = False) -> MechanismSpec:
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"mechanism {spec.name!r} already registered")
    _validate_walk_fn(spec)
    _REGISTRY[spec.name] = spec
    tables_for.cache_clear()
    for hook in _INVALIDATE_HOOKS:
        hook()
    return spec


def get(name: str) -> MechanismSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown mechanism {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def specs_for(names: Tuple[str, ...]) -> Tuple[MechanismSpec, ...]:
    return tuple(get(n) for n in names)


@functools.lru_cache(maxsize=None)
def tables_for(names: Tuple[str, ...]) -> MechTables:
    specs = specs_for(names)
    return MechTables(
        names=tuple(s.name for s in specs),
        n_pte=np.array([s.n_pte for s in specs], np.int32),
        parallel=np.array([s.parallel for s in specs], bool),
        bypass=np.array([s.bypass_l1 for s in specs], bool),
        pwc_on=np.array([s.pwc_levels for s in specs], bool),
        huge=np.array([s.huge for s in specs], bool),
        ideal=np.array([s.ideal for s in specs], bool),
        cache_tlb=np.array([s.cache_tlb for s in specs], bool),
        segment=np.array([s.segment for s in specs], bool),
        colocate=np.array([s.colocate for s in specs], bool),
    )


# ---------------------------------------------------------------------------
# the paper's five mechanisms (Table I / Figs 12-14)
# ---------------------------------------------------------------------------
register(MechanismSpec(
    name="radix", n_pte=4, pwc_levels=(True, True, True, True),
    walk_fn=PT.radix4_walk_lines,
    description="x86-64 4-level radix table; serial pointer chase, "
                "per-level PWCs, PTE fills pollute the caches"))

register(MechanismSpec(
    name="ech", n_pte=2, parallel=True,
    walk_fn=PT.ech_probe_lines,
    description="Elastic Cuckoo Hash table (Skarlatos et al.): d=2 hashed "
                "probes issued in parallel, no PWCs; multi-core allocation "
                "pressure triggers upsizing/rehash churn"))

register(MechanismSpec(
    name="hugepage", n_pte=3, pwc_levels=(True, True, True, False),
    huge=True, walk_fn=PT.hugepage_walk_lines,
    description="2MB pages: 3-level walk and 512x TLB reach, but "
                "fragmentation forces 4KB fallbacks and promotion/fault "
                "stalls grow with allocating cores"))

register(MechanismSpec(
    name="ndpage", n_pte=3, bypass_l1=True, flattened=True,
    pwc_levels=(True, True, False, False),
    walk_fn=PT.ndpage_walk_lines,
    description="NDPage: flattened L2/L1 node (one access), PTE accesses "
                "bypass the NDP L1, PWCs only on the near-ideal L4/L3"))

register(MechanismSpec(
    name="ideal", n_pte=0, ideal=True,
    description="no translation at all — upper bound"))

# One-dataclass extension example: flatten L3/L2/L1 into a single node
# covering 512GB of VA (2^27 entries) so the walk is L4 + one access.
# Trades enormous per-node footprint for the shortest possible non-ideal
# walk; kept OUT of DEFAULT_MECHS so the paper-figure runs are unchanged.
register(MechanismSpec(
    name="ndpage_pl3", n_pte=2, bypass_l1=True, flattened=True,
    pwc_levels=(True, False, False, False),
    walk_fn=PT.ndpage_pl3_walk_lines,
    description="flattened-PL3 NDPage variant: L4 + one merged L3/L2/L1 "
                "access, PTEs bypass L1"))

# Ablation for the paper's L1-bypass on/off sensitivity study: the same
# flattened walk, but PTE fills go through (and pollute) the NDP L1.
# Shares ndpage's walk function, so the sweep engine runs both in ONE
# shape bucket — the bypass flag is per-lane data, not a new compile.
register(MechanismSpec(
    name="ndpage_nobyp", n_pte=3, bypass_l1=False, flattened=True,
    pwc_levels=(True, True, False, False),
    walk_fn=PT.ndpage_walk_lines,
    description="NDPage with L1 bypass DISABLED (sensitivity ablation): "
                "flattened walk kept, but PTE fills compete for the tiny "
                "NDP L1 — degrades toward radix"))

# ---------------------------------------------------------------------------
# design-space search structural variants (repro.sim._search)
# ---------------------------------------------------------------------------
# The search genome's structural half is (flatten level, L1-bypass
# policy, huge-page mapping).  Three of the eight combinations already
# exist above (ndpage, ndpage_nobyp, ndpage_pl3); the remaining five are
# registered here so every combination is one registry lookup away and
# the whole family shares walk FUNCTIONS per flatten level — a search
# generation mixing bypass/huge choices stays in (at most) two compiled
# shape buckets, with the differing flags riding the batch lanes.
register(MechanismSpec(
    name="ndpage_pl3_nobyp", n_pte=2, bypass_l1=False, flattened=True,
    pwc_levels=(True, False, False, False),
    walk_fn=PT.ndpage_pl3_walk_lines,
    description="search variant: flattened-PL3 walk with the L1 bypass "
                "DISABLED — PTE fills compete for the NDP L1"))

register(MechanismSpec(
    name="ndpage_hp", n_pte=3, bypass_l1=True, flattened=True,
    pwc_levels=(True, True, False, False), huge=True,
    walk_fn=PT.ndpage_walk_lines,
    description="search variant: NDPage (flattened PL2/PL1, L1 bypass) "
                "mapping 2MB huge pages — TLB reach vs fragmentation/"
                "promotion stalls"))

register(MechanismSpec(
    name="ndpage_nobyp_hp", n_pte=3, bypass_l1=False, flattened=True,
    pwc_levels=(True, True, False, False), huge=True,
    walk_fn=PT.ndpage_walk_lines,
    description="search variant: flattened PL2/PL1 walk, cached PTE "
                "fills, 2MB huge pages"))

register(MechanismSpec(
    name="ndpage_pl3_hp", n_pte=2, bypass_l1=True, flattened=True,
    pwc_levels=(True, False, False, False), huge=True,
    walk_fn=PT.ndpage_pl3_walk_lines,
    description="search variant: flattened-PL3 walk, L1 bypass, 2MB "
                "huge pages"))

register(MechanismSpec(
    name="ndpage_pl3_nobyp_hp", n_pte=2, bypass_l1=False, flattened=True,
    pwc_levels=(True, False, False, False), huge=True,
    walk_fn=PT.ndpage_pl3_walk_lines,
    description="search variant: flattened-PL3 walk, cached PTE fills, "
                "2MB huge pages"))

# The design-space search's winning configuration (repro.sim._search,
# space "default", seed 20250808): the paper's exact machine geometry
# (32-entry PWC @2cyc, 64x4 L1 DTLB, 1536-entry L2 TLB) but flattening
# PL3/PL2/PL1 instead of PL2/PL1 — it DOMINATES the paper's NDPage
# point on all three search objectives (suite-mean speedup 1.313 vs
# 1.296, worst-case PTW 103.3 vs 109.4 cyc, identical SRAM budget).
# Structurally identical to ndpage_pl3; named separately so the
# search-discovered design point is addressable (and documented) on
# its own, pinned in benchmarks/frontier_baseline.json.
register(MechanismSpec(
    name="ndpage_search", n_pte=2, bypass_l1=True, flattened=True,
    pwc_levels=(True, False, False, False),
    walk_fn=PT.ndpage_pl3_walk_lines,
    description="search winner (space 'default', seed 20250808): "
                "paper geometry + flattened-PL3 walk; dominates the "
                "paper's NDPage config on speedup/SRAM/worst-PTW"))

# ---------------------------------------------------------------------------
# the related-work mechanism zoo (ROADMAP item; docs/zoo.md)
# ---------------------------------------------------------------------------
# Four translation designs the related work actually proposes, each one
# spec + one walk fn.  They need zoo machine knobs to differ from their
# baselines (ctlb_kb for victima, num_stacks for coda — see
# configs.ndp_sim.zoo_machine); on a default machine each degrades to
# its underlying structure by construction.
register(MechanismSpec(
    name="victima", n_pte=4, pwc_levels=(True, True, True, True),
    cache_tlb=True, walk_fn=PT.radix4_walk_lines,
    description="Victima (Kanellopoulos et al., 2310.04158): L2-cache "
                "lines repurposed as a second large set-associative TLB "
                "level probed after an L2-TLB miss; geometry derives "
                "from the repurposed capacity (ctlb_kb = the demotion/"
                "promotion occupancy knob), x86 radix walk underneath"))

register(MechanismSpec(
    name="picorel", n_pte=1, bypass_l1=True, segment=True,
    org="inverted", walk_fn=PT.inverted_hash_lines,
    description="Picorel et al. (1612.00445) near-memory translation: "
                "direct-segment fast path for the contiguous footprint, "
                "one set-associative inverted-hash bucket access for "
                "the fragmentation-broken rest — no radix levels at all"))

register(MechanismSpec(
    name="coda", n_pte=4, pwc_levels=(True, True, True, True),
    colocate=True, walk_fn=PT.radix4_walk_lines,
    description="CODA-style co-location-aware mapping: stock radix "
                "hardware, but vpn->frame placement biases PTEs and "
                "data into the LOCAL NDP stack, dodging the remote-"
                "stack hop penalty on multi-stack machines"))

register(MechanismSpec(
    name="range_table", n_pte=4, pwc_levels=(True, True, False, False),
    org="segment", walk_fn=PT.range_walk_lines,
    description="range/segment-table translation (binary-search "
                "AddrTrans idiom): log2(ranges) probes over sorted "
                "range descriptors; the early probes stay cached, so "
                "miss cost scales with extent fragmentation, not depth"))

#: the four related-work designs, in zoo-report order
ZOO_MECHS: Tuple[str, ...] = ("victima", "picorel", "coda", "range_table")

#: the paper's evaluation set, in figure order — the simulator default
DEFAULT_MECHS: Tuple[str, ...] = ("radix", "ech", "hugepage", "ndpage",
                                  "ideal")
