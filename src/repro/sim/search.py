"""Deprecated import path — the implementation lives in
``repro.sim._search``; import :func:`search` / :class:`SearchSpace`
from :mod:`repro.sim` instead.  ``python -m repro.sim.search`` keeps
working (and stays warning-free: running as ``__main__`` is the CLI,
not an import off the old path)."""
import sys
import warnings

from repro.sim._search import (OBJECTIVES,  # noqa: F401
                               Candidate, SearchResult, SearchSpace,
                               build_machine, dominates,
                               evaluate_genomes, merge_search_section,
                               pareto_indices, resolve_space, search)

if __name__ != "__main__":
    warnings.warn(
        "repro.sim.search is deprecated; import search / SearchSpace "
        "from repro.sim instead",
        DeprecationWarning, stacklevel=2)

if __name__ == "__main__":               # pragma: no cover
    from repro.sim._search import _main
    sys.exit(_main())
