"""Set-associative LRU caches/TLBs as functional scan state.

A cache instance is a dict of arrays:
    tags: (sets, ways) int32   stored tag+1; 0 = invalid
    lru:  (sets, ways) int32   per-way last-use stamp
    ctr:  ()           int32   monotonic stamp counter

``access`` is a pure function; batching over cores / mechanisms is done by
the caller with jax.vmap.  Keys are 64B line ids (caches) or VPNs (TLBs) —
any int32 key space works.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

State = Dict[str, jnp.ndarray]


def make(num_sets: int, ways: int) -> State:
    return {
        "tags": jnp.zeros((num_sets, ways), jnp.int32),
        "lru": jnp.zeros((num_sets, ways), jnp.int32),
        "ctr": jnp.zeros((), jnp.int32),
    }


def access(state: State, key: jnp.ndarray, *, insert: jnp.ndarray,
           enabled: jnp.ndarray) -> Tuple[State, jnp.ndarray]:
    """One lookup (+fill on miss if ``insert``).

    key: () int32; insert/enabled: () bool.  Returns (state, hit).
    ``enabled=False`` leaves the state untouched and reports miss —
    used for bypass (NDPage metadata) and invalid access slots.
    """
    num_sets, ways = state["tags"].shape
    set_ = jax.lax.rem(key, num_sets)
    tag = (jax.lax.div(key, num_sets) + 1).astype(jnp.int32)  # 0 = invalid

    row_tags = state["tags"][set_]                 # (ways,)
    row_lru = state["lru"][set_]
    matches = row_tags == tag
    hit = matches.any() & enabled

    victim = jnp.argmin(row_lru)
    way = jnp.where(hit, jnp.argmax(matches), victim)

    ctr = state["ctr"] + 1
    do_write = enabled & (hit | insert)
    new_tag = jnp.where(hit, tag, jnp.where(insert, tag, row_tags[way]))
    new_tags = state["tags"].at[set_, way].set(
        jnp.where(do_write, new_tag, row_tags[way]))
    new_lru = state["lru"].at[set_, way].set(
        jnp.where(do_write, ctr, row_lru[way]))
    new_state = {"tags": new_tags, "lru": new_lru, "ctr": ctr}
    return new_state, hit
