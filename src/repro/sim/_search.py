"""Automated design-space search: evolve translation configs on the
sweep engine.

The paper hand-picks NDPage's design point (flatten the last two
levels, bypass the L1 for PTEs, fixed PWC/TLB geometry) and never asks
whether a *different* point in the same space dominates it.  This
module asks, with the harness shape neural-architecture-search uses —
seeded random baseline -> objective evaluation -> evolutionary Pareto
loop (mutation + crossover over the frontier) — made near-free by the
sweep engine's shape/data split: every generation's candidates pack as
value-only lanes into :func:`repro.sim.run_bucketed`, ONE
:func:`simulate_batch_varied` dispatch per (machine-shape, walk-fn)
bucket, so compile count is bounded by the bucket count, never the
population size (``runner_cache_info()`` asserts it in tests).

The genome
----------
A candidate is one value per knob of a declarative :class:`SearchSpace`
(presets in ``repro.configs.ndp_sim.SEARCH_SPACES``):

  ``pwc_entries``, ``l2_tlb.entries``, ...   MachineConfig override
                  paths (geometry knobs change compiled shapes)
  ``l1_dtlb``     an (entries, ways) L1-DTLB geometry bundle
  ``flatten``     "pl2" | "pl3" — which levels the flattened node merges
  ``l1_bypass``   PTE fills bypass the NDP L1 (True) or pollute it
  ``huge``        the candidate maps 2MB huge pages

The structural triple (flatten, l1_bypass, huge) selects one of the
eight registered ``ndpage*`` mechanism variants; each candidate is
simulated as ``("radix", <variant>)`` so its speedup baseline rides the
same lanes.

Objectives (multi-objective, named, directional)
------------------------------------------------
  ``mean_speedup``  (max) suite-mean speedup over radix across the
                    figure-suite workloads plus the two committed
                    real-trace fixtures
  ``sram_kb``       (min) an SRAM/area proxy from the geometry knobs:
                    8 bytes per L1-DTLB / L2-TLB entry + 8 bytes per
                    PWC entry per walk level (``MAX_PTE`` levels)
  ``worst_ptw``     (min) worst-case average page-table-walk latency
                    (cycles) across the workload suite

The output is a :class:`SearchResult`: the Pareto frontier (no
dominated points), full provenance (seed, generations, population,
compile counts), and an explicit verdict on whether any discovered
point DOMINATES the paper's NDPage config.  ``benchmarks/sim_search.py``
merges it into BENCH_sim.json under a ``"search"`` key and checks it
against the committed frontier baseline in CI.

Caching / resume
----------------
Evaluated objectives are cached per-candidate to
``.trace_cache/search_evals_*.json`` — flushed after every generation,
keyed on the space, the workload suite (fixture file hashes included),
the trace preset (seed included) and the engine file hashes — so a
resumed or repeated CI run re-dispatches only genomes it has never
seen.  Same search seed + same engine => bit-identical frontier.

CLI:  ``python -m repro.sim.search --smoke`` (the standard seeded
search, >= 200 candidates) or ``--quick`` (1-generation PR smoke);
both merge the ``"search"`` section into BENCH_sim.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.ndp_sim import (PRESETS, SEARCH_SPACES, MachineConfig,
                                   ndp_machine)
from repro.sim.mechanisms import MAX_PTE
from repro.sim.simulator import SimJob, SimResult
from repro.sim._sweep import apply_param, run_bucketed
from repro.util import resilience

#: part of the eval-cache key: bump on any change to the evaluation or
#: objective derivation in this module
_SEARCH_VERSION = 1

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

#: knobs that select the candidate's mechanism STRUCTURE instead of a
#: MachineConfig override
STRUCT_KNOBS = ("flatten", "l1_bypass", "huge")

#: (flatten, l1_bypass, huge) -> registered mechanism name
MECH_BY_STRUCT: Dict[Tuple[str, bool, bool], str] = {
    ("pl2", True, False): "ndpage",
    ("pl2", False, False): "ndpage_nobyp",
    ("pl2", True, True): "ndpage_hp",
    ("pl2", False, True): "ndpage_nobyp_hp",
    ("pl3", True, False): "ndpage_pl3",
    ("pl3", False, False): "ndpage_pl3_nobyp",
    ("pl3", True, True): "ndpage_pl3_hp",
    ("pl3", False, True): "ndpage_pl3_nobyp_hp",
}

#: the paper's NDPage design point, per knob — knobs a space omits fall
#: back to these, and the paper candidate (always evaluated, generation
#: 0) is exactly this genome restricted to the space's knobs
PAPER_DEFAULTS: "OrderedDict[str, object]" = OrderedDict([
    ("pwc_entries", 32),
    ("pwc_latency", 2),
    ("l1_dtlb", (64, 4)),
    ("l2_tlb.entries", 1536),
    ("flatten", "pl2"),
    ("l1_bypass", True),
    ("huge", False),
    # direct mechanism pick (the zoo space); "ndpage" = defer to the
    # structural triple above
    ("zoo_mech", "ndpage"),
    # zoo machine knobs: the paper machine carves no cache into a
    # cache-as-TLB and models a single memory stack
    ("ctlb_kb", 0),
    ("num_stacks", 1),
    # DRAM model preset (the "memory" space flips this to "banked");
    # the paper's numbers are calibrated on the bounded-linear model
    ("memory_model", "bounded_linear"),
])

#: named objectives with their optimization direction
OBJECTIVES: Tuple[Tuple[str, str], ...] = (
    ("mean_speedup", "max"),
    ("sram_kb", "min"),
    ("worst_ptw", "min"),
)


# ---------------------------------------------------------------------------
# the declarative space
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """One declarative design space + search sizing (see module doc)."""

    name: str
    knobs: Tuple[Tuple[str, Tuple], ...]     # ordered (name, values)
    cores: int
    workloads: Tuple[str, ...]
    n_random: int
    population: int
    generations: int
    offspring: int
    trace_len: int
    chunk: int
    preset: str
    seed: int

    def __post_init__(self):
        for name, values in self.knobs:
            if not values:
                raise ValueError(f"knob {name!r} has no values")
            if len(set(values)) != len(values):
                raise ValueError(f"knob {name!r} has duplicate values")

    @property
    def knob_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.knobs)

    def size(self) -> int:
        return int(np.prod([len(v) for _, v in self.knobs]))

    @classmethod
    def named(cls, name: str) -> "SearchSpace":
        try:
            spec = dict(SEARCH_SPACES[name])
        except KeyError:
            raise KeyError(f"unknown search space {name!r}; available: "
                           f"{sorted(SEARCH_SPACES)}") from None
        spec["knobs"] = tuple((n, tuple(v)) for n, v in spec["knobs"])
        spec["workloads"] = tuple(spec["workloads"])
        return cls(name=name, **spec)


def resolve_space(space: "SearchSpace | str") -> SearchSpace:
    return SearchSpace.named(space) if isinstance(space, str) else space


# ---------------------------------------------------------------------------
# genomes
# ---------------------------------------------------------------------------
def paper_genome(space: SearchSpace) -> Tuple:
    """The paper's design point expressed in this space's knobs."""
    return tuple(PAPER_DEFAULTS[n] for n in space.knob_names)


def genome_dict(space: SearchSpace, genome: Tuple
                ) -> "OrderedDict[str, object]":
    return OrderedDict(zip(space.knob_names, genome))


def genome_key(space: SearchSpace, genome: Tuple) -> str:
    """Stable JSON key for one genome (tuples become lists)."""
    return json.dumps(list(genome_dict(space, genome).items()),
                      default=list)


def _knob(space: SearchSpace, genome: Tuple, name: str):
    names = space.knob_names
    return (genome[names.index(name)] if name in names
            else PAPER_DEFAULTS[name])


def mech_for(space: SearchSpace, genome: Tuple) -> str:
    """The registered mechanism variant this genome selects: an explicit
    ``zoo_mech`` knob wins outright (zoo spaces search over whole
    designs, not NDPage structure); ``"ndpage"`` or an absent knob
    defers to the structural triple."""
    zoo = _knob(space, genome, "zoo_mech")
    if zoo != "ndpage":
        return str(zoo)
    struct = (_knob(space, genome, "flatten"),
              bool(_knob(space, genome, "l1_bypass")),
              bool(_knob(space, genome, "huge")))
    return MECH_BY_STRUCT[struct]


def build_machine(space: SearchSpace, genome: Tuple) -> MachineConfig:
    """The candidate's NDP machine: the base ndp config with every
    geometry knob applied."""
    mach = ndp_machine(space.cores)
    for name, value in genome_dict(space, genome).items():
        if name in STRUCT_KNOBS or name == "zoo_mech":
            continue
        if name == "l1_dtlb":
            entries, ways = value
            mach = apply_param(mach, "l1_dtlb.entries", int(entries))
            mach = apply_param(mach, "l1_dtlb.ways", int(ways))
        else:
            mach = apply_param(mach, name, value)
    return mach


def sram_kb(space: SearchSpace, genome: Tuple) -> float:
    """SRAM/area proxy (KB) of the genome's translation structures:
    8 bytes per TLB entry (tag + PPN) and 8 bytes per PWC entry per
    walk level (the PWC table is ``MAX_PTE`` sets x ``pwc_entries``
    ways).  Analytic in the genome, so the objective is exact and
    deterministic."""
    dtlb_entries, _ = _knob(space, genome, "l1_dtlb")
    sram_bytes = (8 * int(_knob(space, genome, "pwc_entries")) * MAX_PTE
                  + 8 * int(dtlb_entries)
                  + 8 * int(_knob(space, genome, "l2_tlb.entries")))
    return sram_bytes / 1024.0


# ---------------------------------------------------------------------------
# dominance / Pareto frontier
# ---------------------------------------------------------------------------
def dominates(a: Dict[str, float], b: Dict[str, float],
              objectives: Sequence[Tuple[str, str]] = OBJECTIVES) -> bool:
    """True iff objective vector ``a`` dominates ``b``: at least as good
    on every objective (directionally) and strictly better on one."""
    strict = False
    for name, direction in objectives:
        va, vb = a[name], b[name]
        if direction == "min":
            va, vb = -va, -vb
        if va < vb:
            return False
        if va > vb:
            strict = True
    return strict


def pareto_indices(vectors: Sequence[Dict[str, float]],
                   objectives: Sequence[Tuple[str, str]] = OBJECTIVES
                   ) -> List[int]:
    """Indices of the non-dominated vectors, in input order."""
    return [i for i, v in enumerate(vectors)
            if not any(dominates(w, v, objectives)
                       for j, w in enumerate(vectors) if j != i)]


# ---------------------------------------------------------------------------
# evaluated candidates
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Candidate:
    """One evaluated genome."""

    genome: "OrderedDict[str, object]"
    mech: str
    objectives: Dict[str, float]
    per_workload: Dict[str, float]      # workload -> speedup over radix
    origin: str                          # paper|random|mutation|crossover
    gen: int

    def to_json_dict(self) -> Dict:
        return {"genome": {k: (list(v) if isinstance(v, tuple) else v)
                           for k, v in self.genome.items()},
                "mech": self.mech,
                "objectives": {k: round(v, 6)
                               for k, v in self.objectives.items()},
                "per_workload": {k: round(v, 6)
                                 for k, v in self.per_workload.items()},
                "origin": self.origin, "gen": self.gen}


@dataclasses.dataclass
class SearchResult:
    """Everything one search run produced: every evaluated candidate,
    the Pareto frontier (no dominated points, deterministically
    ordered), the paper-config verdict, and full provenance."""

    space: SearchSpace
    objectives: Tuple[Tuple[str, str], ...]
    candidates: List[Candidate]
    frontier: List[Candidate]
    paper: Candidate
    verdict: Dict
    provenance: Dict

    def to_json_dict(self) -> Dict:
        return {
            "space": self.space.name,
            "space_size": self.space.size(),
            "objectives": [{"name": n, "direction": d}
                           for n, d in self.objectives],
            "evaluated": len(self.candidates),
            "frontier": [c.to_json_dict() for c in self.frontier],
            "paper": self.paper.to_json_dict(),
            "verdict": self.verdict,
            "provenance": self.provenance,
        }


def _frontier_sort_key(c: Candidate):
    return (-c.objectives["mean_speedup"], c.objectives["sram_kb"],
            c.objectives["worst_ptw"], json.dumps(
                list(c.genome.items()), default=list))


# ---------------------------------------------------------------------------
# evaluation: populations -> value-only lanes on the sweep engine
# ---------------------------------------------------------------------------
def _abs_workload(workload: str) -> str:
    """Absolutize a relative ``trace:`` fixture path against the repo
    root (the declarative spaces keep paths portable/relative)."""
    from repro.workloads import parse_workload_spec
    spec = parse_workload_spec(workload)
    if spec.kind != "trace" or os.path.isabs(spec.name):
        return workload
    return spec.with_path(os.path.join(_ROOT, spec.name)).canonical()


_TRACES: Dict[Tuple, Dict] = {}


def _trace_table(space: SearchSpace) -> Dict[str, Dict]:
    """workload -> trace dict for this space, generated once per
    process (both sides additionally memoize on disk)."""
    from repro.workloads import generate_trace
    sim_preset = PRESETS[space.preset]
    out = {}
    for wl in space.workloads:
        key = (wl, space.cores, space.trace_len, space.preset)
        if key not in _TRACES:
            _TRACES[key] = generate_trace(
                _abs_workload(wl), space.cores, length=space.trace_len,
                seed=sim_preset.seed, preset=sim_preset)
        out[wl] = _TRACES[key]
    return out


def _objectives_from_results(space: SearchSpace, genome: Tuple,
                             mech: str, results: Sequence[SimResult]
                             ) -> Tuple[Dict[str, float], Dict[str, float]]:
    per_wl = {wl: float(res.speedup_vs("radix")[mech])
              for wl, res in zip(space.workloads, results)}
    worst = max(float(res.scalar("avg_ptw_latency", mech))
                for res in results)
    obj = {"mean_speedup": float(np.mean(list(per_wl.values()))),
           "sram_kb": sram_kb(space, genome),
           "worst_ptw": worst}
    return obj, per_wl


def evaluate_genomes(space: SearchSpace, genomes: Sequence[Tuple], *,
                     cache: Dict | None = None,
                     devices: int | None = None,
                     checkpoint: "bool | str | None" = None,
                     watchdog_s: float | None = None
                     ) -> Tuple[List[Tuple[Dict, Dict, str]], Dict]:
    """Evaluate a batch of genomes: each becomes ``len(workloads)``
    value-only lanes of the bucketed sweep dispatch (one
    ``simulate_batch_varied`` per (machine-shape, walk-fn) bucket).

    Returns (per-genome ``(objectives, per_workload, mech)`` in input
    order, dispatch stats).  ``cache`` (genome-key -> stored eval) is
    consulted and updated in place; cached genomes never re-dispatch.
    ``checkpoint``/``watchdog_s`` pass straight to
    :func:`repro.sim.run_bucketed` (crash-resume + hung-dispatch
    retry; both off by default).
    """
    cache = {} if cache is None else cache
    stats = {"points": 0, "buckets": 0, "runner_compiles": 0,
             "distinct_shapes": 0, "wall_s": 0.0, "per_bucket": [],
             "cache_hits": 0}
    fresh: List[Tuple] = []
    for g in genomes:
        if genome_key(space, g) in cache:
            stats["cache_hits"] += 1
        elif g not in fresh:
            fresh.append(g)

    if fresh:
        traces = _trace_table(space)
        jobs = []
        for g in fresh:
            mach = build_machine(space, g)
            mech = mech_for(space, g)
            jobs.extend(SimJob(mach, traces[wl], ("radix", mech))
                        for wl in space.workloads)
        outs, dstats = run_bucketed(jobs, chunk=space.chunk,
                                    devices=devices,
                                    checkpoint=checkpoint,
                                    watchdog_s=watchdog_s)
        for k in ("points", "buckets", "runner_compiles",
                  "distinct_shapes", "wall_s"):
            stats[k] = dstats[k]
        stats["per_bucket"] = dstats["per_bucket"]
        n_wl = len(space.workloads)
        for i, g in enumerate(fresh):
            mech = mech_for(space, g)
            obj, per_wl = _objectives_from_results(
                space, g, mech, outs[i * n_wl:(i + 1) * n_wl])
            cache[genome_key(space, g)] = {
                "objectives": obj, "per_workload": per_wl, "mech": mech}

    out = []
    for g in genomes:
        e = cache[genome_key(space, g)]
        out.append((dict(e["objectives"]), dict(e["per_workload"]),
                    e["mech"]))
    return out, stats


# ---------------------------------------------------------------------------
# the on-disk eval cache (per-generation / resume support)
# ---------------------------------------------------------------------------
def _engine_digest(space: SearchSpace) -> str:
    """Hash of everything the objective values depend on besides the
    genome: engine/bucketing/generator/page-table sources, this module's
    version, the mechanism registry's candidate specs, and the fixture
    trace files themselves."""
    import repro.core.page_table        # noqa: F401
    import repro.sim.memory_model       # noqa: F401
    import repro.sim._sweep             # noqa: F401
    import repro.sim.simulator          # noqa: F401
    import repro.workloads.generators   # noqa: F401
    from repro.sim import mechanisms as MS
    h = hashlib.sha256()
    h.update(str(_SEARCH_VERSION).encode())
    # mechanisms.py is hashed WHOLESALE: a zoo space's ``zoo_mech`` knob
    # can reach any registered spec, so per-spec hashing can't cover it
    for name in ("repro.sim.simulator", "repro.sim._sweep",
                 "repro.core.page_table", "repro.workloads.generators",
                 "repro.sim.mechanisms", "repro.sim.memory_model"):
        with open(sys.modules[name].__file__, "rb") as f:
            h.update(f.read())
    reachable = set(MECH_BY_STRUCT.values())
    for kn, values in space.knobs:
        if kn == "zoo_mech":
            reachable.update(str(v) for v in values if v != "ndpage")
    for name in ("radix",) + tuple(sorted(reachable)):
        s = MS.get(name)
        h.update(repr((s.name, s.n_pte, s.parallel, s.bypass_l1,
                       s.pwc_levels, s.huge, s.flattened, s.ideal,
                       s.cache_tlb, s.segment, s.colocate, s.org,
                       getattr(s.walk_fn, "__qualname__", None))).encode())
    for wl in space.workloads:
        if wl.startswith("trace:"):
            path = _abs_workload(wl)[len("trace:"):].partition("?")[0]
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()


def _eval_cache_path(space: SearchSpace) -> str | None:
    from repro.workloads import trace_cache_dir
    d = trace_cache_dir()
    if d is None:
        return None
    key_src = json.dumps({
        "knobs": [[n, list(v)] for n, v in space.knobs],
        "cores": space.cores, "workloads": list(space.workloads),
        "trace_len": space.trace_len, "chunk": space.chunk,
        "preset": dataclasses.asdict(PRESETS[space.preset]),
        "engine": _engine_digest(space),
    }, sort_keys=True, default=list)
    h = hashlib.sha256(key_src.encode()).hexdigest()[:20]
    return os.path.join(d, f"search_evals_{space.name}_{h}.json")


def _eval_cache_load(path: str | None) -> Dict:
    """Integrity-checked eval-cache load (sha256 sidecar, quarantine on
    corruption); a bad cache re-evaluates instead of crashing a resumed
    search."""
    if path is None:
        return {}
    data = resilience.read_json(path)
    if isinstance(data, dict):
        return data
    if data is not None:
        resilience.quarantine(path, "eval cache is not a dict")
    return {}


def _eval_cache_store(path: str | None, cache: Dict) -> None:
    if path is None:
        return
    # atomic + sidecar; filesystem failure degrades to cache-off
    resilience.write_json(path, cache)


# ---------------------------------------------------------------------------
# sampling / variation (all deterministic under the seeded Generator)
# ---------------------------------------------------------------------------
def _random_genome(rng: np.random.Generator, space: SearchSpace) -> Tuple:
    return tuple(values[rng.integers(len(values))]
                 for _, values in space.knobs)


def _sample_unique(rng: np.random.Generator, space: SearchSpace, n: int,
                   seen: set) -> List[Tuple]:
    out: List[Tuple] = []
    tries = 0
    limit = max(50 * n, 500)
    while len(out) < n and tries < limit:
        tries += 1
        g = _random_genome(rng, space)
        if g not in seen:
            seen.add(g)
            out.append(g)
    return out


def _mutate(rng: np.random.Generator, space: SearchSpace,
            parent: Tuple) -> Tuple:
    g = list(parent)
    n_flip = 1 + int(rng.random() < 0.3)
    for ki in rng.choice(len(space.knobs),
                         size=min(n_flip, len(space.knobs)),
                         replace=False):
        values = [v for v in space.knobs[ki][1] if v != g[ki]]
        if values:
            g[ki] = values[rng.integers(len(values))]
    return tuple(g)


def _crossover(rng: np.random.Generator, a: Tuple, b: Tuple) -> Tuple:
    return tuple(x if rng.random() < 0.5 else y for x, y in zip(a, b))


def _breed(rng: np.random.Generator, space: SearchSpace,
           parents: List[Tuple], n: int, seen: set
           ) -> List[Tuple[Tuple, str]]:
    """Up to ``n`` unseen offspring as (genome, origin) pairs."""
    out: List[Tuple[Tuple, str]] = []
    tries = 0
    limit = max(50 * n, 500)
    while len(out) < n and tries < limit:
        tries += 1
        if len(parents) >= 2 and rng.random() < 0.5:
            i, j = rng.choice(len(parents), size=2, replace=False)
            g, origin = _crossover(rng, parents[i], parents[j]), "crossover"
        else:
            g = _mutate(rng, space,
                        parents[rng.integers(len(parents))])
            origin = "mutation"
        if g not in seen:
            seen.add(g)
            out.append((g, origin))
    return out


# ---------------------------------------------------------------------------
# the search loop
# ---------------------------------------------------------------------------
def search(space: "SearchSpace | str" = "default", *,
           seed: int | None = None, use_cache: bool = True,
           devices: int | None = None,
           checkpoint: "bool | str | None" = None,
           watchdog_s: float | None = None) -> SearchResult:
    """Run the seeded design-space search (see module docstring).

    Deterministic: the same ``seed`` (default: the space's pinned seed)
    over the same space and engine produces a bit-identical frontier,
    with or without a warm eval cache.  A killed run resumes on two
    levels: the persisted eval cache skips whole finished generations,
    and ``checkpoint=True`` additionally restores any finished dispatch
    buckets of the generation that was in flight (see
    :func:`repro.sim.run_bucketed`).
    """
    space = resolve_space(space)
    seed = space.seed if seed is None else seed
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()

    cache_path = _eval_cache_path(space) if use_cache else None
    cache = _eval_cache_load(cache_path)
    cache_hits0 = 0

    seen_genomes: set = set()
    by_key: "OrderedDict[str, Candidate]" = OrderedDict()
    totals = {"runner_compiles": 0, "dispatch_buckets": 0,
              "eval_cache_hits": 0, "lanes": 0}
    bucket_keys: set = set()

    def submit(batch: List[Tuple[Tuple, str]], gen: int) -> None:
        genomes = [g for g, _ in batch]
        evals, stats = evaluate_genomes(space, genomes, cache=cache,
                                        devices=devices,
                                        checkpoint=checkpoint,
                                        watchdog_s=watchdog_s)
        totals["runner_compiles"] += stats["runner_compiles"]
        totals["dispatch_buckets"] += stats["buckets"]
        totals["eval_cache_hits"] += stats["cache_hits"]
        totals["lanes"] += stats["points"]
        for b in stats["per_bucket"]:
            bucket_keys.add((b["shape"], tuple(b["walk_fns"])))
        for (g, origin), (obj, per_wl, mech) in zip(batch, evals):
            by_key[genome_key(space, g)] = Candidate(
                genome=genome_dict(space, g), mech=mech,
                objectives=obj, per_workload=per_wl,
                origin=origin, gen=gen)
        _eval_cache_store(cache_path, cache)   # per-generation flush

    # generation 0: the paper's design point + the random baseline
    paper = paper_genome(space)
    seen_genomes.add(paper)
    gen0 = [(paper, "paper")]
    gen0 += [(g, "random") for g in _sample_unique(
        rng, space, space.n_random, seen_genomes)]
    cache_hits0 = len(cache)
    submit(gen0, gen=0)

    # evolutionary Pareto loop: parents are the current frontier
    generations_run = 0
    for g in range(1, space.generations + 1):
        cands = list(by_key.values())
        front = pareto_indices([c.objectives for c in cands],
                               OBJECTIVES)
        parents = [tuple(cands[i].genome.values()) for i in front]
        if len(parents) < 2:
            best = max(cands, key=lambda c: c.objectives["mean_speedup"])
            bg = tuple(best.genome.values())
            if bg not in parents:
                parents.append(bg)
        offspring = _breed(rng, space, parents, space.offspring,
                           seen_genomes)
        # the frontier's mutation/crossover neighborhood can dry up in
        # late generations — top the generation up with fresh random
        # genomes so the evaluation budget is actually spent
        if len(offspring) < space.offspring:
            offspring += [(g, "random") for g in _sample_unique(
                rng, space, space.offspring - len(offspring),
                seen_genomes)]
        if not offspring:                # space exhausted
            break
        submit(offspring, gen=g)
        generations_run = g

    cands = list(by_key.values())
    front_idx = pareto_indices([c.objectives for c in cands], OBJECTIVES)
    frontier = sorted((cands[i] for i in front_idx),
                      key=_frontier_sort_key)

    paper_cand = by_key[genome_key(space, paper)]
    dominating = sorted(
        (c for c in cands
         if dominates(c.objectives, paper_cand.objectives, OBJECTIVES)),
        key=_frontier_sort_key)
    verdict = {
        "dominates_paper": bool(dominating),
        "paper_objectives": {k: round(v, 6) for k, v in
                             paper_cand.objectives.items()},
        "paper_on_frontier": any(c is paper_cand for c in frontier),
        "dominating_points": [c.to_json_dict() for c in dominating[:5]],
        "n_dominating": len(dominating),
    }
    provenance = {
        "seed": seed,
        "generations": generations_run,
        "population": space.population,
        "n_random": space.n_random,
        "offspring_per_gen": space.offspring,
        "evaluated": len(cands),
        "lanes_dispatched": totals["lanes"],
        "runner_compiles": totals["runner_compiles"],
        "dispatch_buckets": totals["dispatch_buckets"],
        "distinct_buckets": len(bucket_keys),
        "eval_cache_hits": totals["eval_cache_hits"],
        "eval_cache_warm_start": cache_hits0,
        "trace_len": space.trace_len,
        "chunk": space.chunk,
        "workloads": list(space.workloads),
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    return SearchResult(space=space, objectives=OBJECTIVES,
                        candidates=cands, frontier=frontier,
                        paper=paper_cand, verdict=verdict,
                        provenance=provenance)


# ---------------------------------------------------------------------------
# BENCH_sim.json merge + CLI
# ---------------------------------------------------------------------------
def merge_search_section(section: Dict, path: str) -> None:
    """Attach ``section`` under the ``"search"`` key of BENCH_sim.json
    without clobbering the figures/sweeps/real_traces/serving sections
    already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the search section only",
                  file=sys.stderr)
    data["search"] = section
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def _main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="the standard seeded search (space 'default': "
                         ">= 200 candidates, <= 10 generations)")
    ap.add_argument("--quick", action="store_true",
                    help="1-generation PR-lane smoke (space 'quick')")
    ap.add_argument("--space", default=None,
                    help="explicit space name (overrides --smoke/--quick)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the space's pinned seed")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore the on-disk eval cache")
    ap.add_argument("--out", default=os.path.join(_ROOT, "BENCH_sim.json"),
                    help="BENCH json to merge the 'search' section into")
    args = ap.parse_args(argv)
    name = args.space or ("quick" if args.quick else "default")

    # same cache plumbing as benchmarks/run.py (src can't import it)
    import jax
    cache_dir = os.environ.get("REPRO_JAX_CACHE_DIR",
                               os.path.join(_ROOT, ".jax_cache"))
    if cache_dir:
        jax.config.update("jax_compilation_cache_dir",
                          os.path.abspath(cache_dir))
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          1.0)

    result = search(name, seed=args.seed, use_cache=not args.no_cache)
    p = result.provenance
    print(f"search space={name} seed={p['seed']} "
          f"evaluated={p['evaluated']}/{result.space.size()} "
          f"gens={p['generations']} compiles={p['runner_compiles']} "
          f"buckets={p['distinct_buckets']} wall={p['wall_s']}s")
    print("frontier (mean_speedup / sram_kb / worst_ptw):")
    for c in result.frontier:
        o = c.objectives
        print(f"  {o['mean_speedup']:.4f} / {o['sram_kb']:.2f}KB / "
              f"{o['worst_ptw']:.1f}cyc  {c.mech:<22} "
              f"{dict(c.genome)}")
    v = result.verdict
    print(f"paper config {v['paper_objectives']} -> "
          + ("DOMINATED by "
             f"{v['n_dominating']} discovered point(s)"
             if v["dominates_paper"] else
             "not dominated by any discovered point"))
    merge_search_section(result.to_json_dict(), args.out)
    print(f"# merged 'search' section into {args.out}")
    return 0 if result.frontier else 1


if __name__ == "__main__":               # pragma: no cover
    sys.exit(_main())
