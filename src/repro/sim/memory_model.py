"""Declarative DRAM memory-model specs — the single source of memory timing.

A :class:`MemoryModel` replaces the loose ``mem_latency /
mem_bandwidth_gbs / mem_service`` scalars that used to live directly on
``MachineConfig``.  Two named presets:

* ``bounded_linear`` — the original engine model: one flat access
  latency for every memory touch plus an aggregate bounded-linear queue
  (``q = service * rho * K``).  The default, and bit-exact vs the
  pre-MemoryModel engine (tests/test_memory_model.py pins this).
* ``banked`` — per-bank row-buffer model: DRAM is ``num_banks`` banks,
  each holding ONE open row of ``row_buffer_bytes``.  An access whose
  bank still has its row open pays ``overhead + t_cas``; a closed-row
  access pays the full ``overhead + t_rp + t_rcd + t_cas`` (precharge +
  activate + column read).  The queue becomes per-bank: traffic on bank
  0 never delays bank 1.  This is what prices the paper's structural
  claim — flat-table walks over contiguous leaf spans keep hitting open
  rows, while radix per-node allocations land on scattered rows.

Address -> (bank, row) mapping is the standard open-page row-interleave
over 64B line ids (the engine's address space, see
:mod:`repro.core.page_table`)::

    col  = line % lines_per_row          # within the open row
    bank = (line / lines_per_row) % num_banks
    row  = line / (lines_per_row * num_banks)

Shape/data split: ``kind``, ``num_banks`` and ``row_buffer_bytes`` are
SHAPE — they change carried-state array shapes and the packed hit-bit
layout, so they key the compiled-runner cache (via
``MachineShape.memory``).  Every latency/timing field is value-only
DATA riding the jit as an operand: a sweep over ``t_cas``/``t_rp``/
``service`` never recompiles.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Tuple

#: DRAM/cache line size the whole engine assumes
LINE_BYTES = 64

#: bounded-linear queue slope (cycles at rho = 1) and the saturation
#: clip — shared by the aggregate (bounded_linear) and per-bank (banked)
#: queue laws
QUEUE_K = 6.5
RHO_MAX = 0.96

KINDS = ("bounded_linear", "banked")

#: fields that are SHAPE (compiled into the runner); everything else is
#: value-only data
SHAPE_FIELDS = ("kind", "num_banks", "row_buffer_bytes")


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """One machine's memory system, declaratively.

    ``latency`` is the flat full-access latency of the bounded model;
    under ``banked`` it is carried for reference/calibration (the
    closed-row total ``overhead + t_rp + t_rcd + t_cas`` is what the
    engine charges — :func:`with_kind` re-derives ``overhead`` so the
    closed-row total matches the machine's calibrated ``latency``).
    ``service`` is the queue service time per 64B line: aggregate for
    ``bounded_linear``, PER BANK for ``banked`` (a bank is busy ~tRC per
    random access it serves).
    """

    kind: str = "bounded_linear"
    latency: float = 170.0          # DDR4 ~65ns @2.6GHz
    bandwidth_gbs: float = 19.2
    service: float = 14.0
    # --- banked geometry (SHAPE: keys the compiled-runner cache) ---
    num_banks: int = 16
    row_buffer_bytes: int = 2048
    # --- banked timings (DATA: sweepable without recompiling) ---
    t_rcd: float = 30.0             # activate (RAS-to-CAS)
    t_rp: float = 30.0              # precharge
    t_cas: float = 25.0             # column read
    overhead: float = 15.0          # controller + interconnect per access

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown memory model kind {self.kind!r}: one of {KINDS}")
        for f in ("latency", "bandwidth_gbs", "service",
                  "t_rcd", "t_rp", "t_cas", "overhead"):
            v = float(getattr(self, f))
            if v < 0.0:
                raise ValueError(f"MemoryModel.{f} must be >= 0, got {v}")
            object.__setattr__(self, f, v)
        for f in ("num_banks", "row_buffer_bytes"):
            object.__setattr__(self, f, int(getattr(self, f)))
        if self.num_banks < 1:
            raise ValueError(f"num_banks must be >= 1, got {self.num_banks}")
        if (self.row_buffer_bytes < LINE_BYTES
                or self.row_buffer_bytes % LINE_BYTES):
            raise ValueError(
                f"row_buffer_bytes must be a positive multiple of "
                f"{LINE_BYTES}, got {self.row_buffer_bytes}")

    # -- derived timings ----------------------------------------------------
    @property
    def lines_per_row(self) -> int:
        return self.row_buffer_bytes // LINE_BYTES

    def miss_latency(self) -> float:
        """Cycles for a closed-row (or bounded-model) memory access."""
        if self.kind == "banked":
            return self.overhead + self.t_rp + self.t_rcd + self.t_cas
        return self.latency

    def hit_latency(self) -> float:
        """Cycles for an open-row access (banked); = miss for bounded."""
        if self.kind == "banked":
            return self.overhead + self.t_cas
        return self.latency

    def row_hit_save(self) -> float:
        """Cycles an open-row hit saves vs a closed-row access: the
        precharge + activate the hit skips.  0.0 for bounded_linear."""
        if self.kind == "banked":
            return self.t_rp + self.t_rcd
        return 0.0

    def line_cycles(self, contiguous: bool) -> float:
        """Cost-model price of one additional PTE line fetched during a
        multi-line rebuild/refill: contiguous spans (flat tables,
        segment descriptors) stream through an open row, per-node
        allocations (radix, inverted buckets) land on closed rows."""
        if self.kind == "banked" and contiguous:
            return self.hit_latency()
        return self.miss_latency()

    def shape_key(self) -> Tuple:
        """The SHAPE half, hashable — part of ``MachineShape``.  All
        bounded machines share one key (the banked geometry fields are
        inert there), so existing compiled-runner sharing is unchanged."""
        if self.kind == "banked":
            return ("banked", self.num_banks, self.row_buffer_bytes)
        return ("bounded_linear",)


#: named presets.  ``banked`` is calibrated for the NDP logic-layer
#: machine: tRP/tRCD/tCAS at HBM2-class cycle counts with miss total
#: overhead+30+30+25 = 100 cycles (= the ndp machine's calibrated
#: latency) and a per-bank service of ~tRC (45ns ~= 117 cycles @2.6GHz)
#: — the bounded ndp service of 46.0 was documented as tRC/active-banks,
#: which the per-bank queue now models structurally.
MEMORY_MODELS = {
    "bounded_linear": MemoryModel(),
    "banked": MemoryModel(kind="banked", latency=100.0,
                          bandwidth_gbs=307.2, service=117.0,
                          num_banks=16, row_buffer_bytes=2048,
                          t_rcd=30.0, t_rp=30.0, t_cas=25.0,
                          overhead=15.0),
}


def resolve_memory_model(spec) -> MemoryModel:
    """Normalize a ``MachineConfig.memory`` value: ``None`` -> the
    bounded_linear default, a preset name -> the registry entry, a field
    dict -> ``MemoryModel(**spec)``, a ``MemoryModel`` -> itself."""
    if spec is None:
        return MEMORY_MODELS["bounded_linear"]
    if isinstance(spec, MemoryModel):
        return spec
    if isinstance(spec, str):
        if spec not in MEMORY_MODELS:
            raise KeyError(
                f"unknown memory model preset {spec!r}: "
                f"one of {tuple(MEMORY_MODELS)}")
        return MEMORY_MODELS[spec]
    if isinstance(spec, dict):
        return MemoryModel(**spec)
    raise TypeError(
        f"MachineConfig.memory must be a MemoryModel, preset name, field "
        f"dict, or None — got {type(spec).__name__}")


def with_kind(cur: MemoryModel, name: str) -> MemoryModel:
    """Switch ``cur`` to preset ``name`` while keeping the machine's own
    calibration: ``latency``/``bandwidth_gbs`` always carry over, and

    * -> ``banked``: ``overhead`` is re-derived so the closed-row total
      equals the machine's calibrated access latency (an ndp machine's
      banked misses cost 100 cycles, a cpu's 170);
    * -> ``bounded_linear``: the aggregate ``service`` carries over too
      (it is machine calibration, not preset data).

    This is what the ``memory_model`` sweep/search knob applies.
    """
    preset = resolve_memory_model(name)
    if preset.kind == "banked":
        return dataclasses.replace(
            preset, latency=cur.latency, bandwidth_gbs=cur.bandwidth_gbs,
            overhead=max(
                cur.latency - (preset.t_rp + preset.t_rcd + preset.t_cas),
                0.0))
    return dataclasses.replace(preset, latency=cur.latency,
                               bandwidth_gbs=cur.bandwidth_gbs,
                               service=cur.service)


# ---------------------------------------------------------------------------
# address mapping + queue law (generic over numpy / jax arrays / scalars)
# ---------------------------------------------------------------------------
def bank_of(line, num_banks: int, lines_per_row: int):
    """64B line id -> bank index (row-interleaved open-page mapping)."""
    return (line // lines_per_row) % num_banks


def row_of(line, num_banks: int, lines_per_row: int):
    """64B line id -> row id within its bank."""
    return line // (lines_per_row * num_banks)


def queue_delay(rate, service):
    """Bounded-linear queue law ``q = service * rho * K`` with
    ``rho = clip(rate * service, 0, RHO_MAX)``.  Elementwise: applied
    per (mech,) aggregate for bounded_linear and per (mech, bank) for
    banked — per-bank independence (bank-0 traffic never delays bank 1)
    is structural, not a tuning choice."""
    import jax.numpy as jnp
    rho = jnp.clip(rate * service, 0.0, RHO_MAX)
    return service * rho * QUEUE_K


# ---------------------------------------------------------------------------
# the one DeprecationWarning for the legacy flat kwargs / sweep paths
# ---------------------------------------------------------------------------
_WARNED_LEGACY = False

#: legacy MachineConfig field -> MemoryModel field
LEGACY_FIELDS = {"mem_latency": "latency",
                 "mem_bandwidth_gbs": "bandwidth_gbs",
                 "mem_service": "service"}


def warn_legacy_memory(what: str) -> None:
    """Warn ONCE per process about the deprecated flat memory fields —
    shared by the ``MachineConfig`` kwarg shim and the sweep-path
    rewrite, so a sweep over legacy paths emits a single warning."""
    global _WARNED_LEGACY
    if _WARNED_LEGACY:
        return
    _WARNED_LEGACY = True
    warnings.warn(
        f"{what} is deprecated: memory timing now lives on "
        "MachineConfig.memory (a repro.sim.memory_model.MemoryModel); "
        "use memory=MemoryModel(...)/memory.<field> paths instead",
        DeprecationWarning, stacklevel=3)
