"""Trace-driven timing simulator for NDP/CPU address translation.

Mechanistic interval model (Sniper-style): every trace entry is one memory
instruction preceded by ``work`` non-memory instructions.  Per entry we
model, for all five mechanisms at once (leading M axis) and all cores
(C axis):

  1. L1 DTLB lookup (free on hit) -> L2 TLB (12cy) -> page-table walk
  2. the walk's PTE accesses: per-level PWC, then cache hierarchy or —
     for NDPage — a direct memory access (L1 bypass), serial for
     radix/hugepage/ndpage, parallel (max) for ECH
  3. the data access through the cache hierarchy
  4. a shared-memory queueing delay from aggregate measured demand
     (M/M/1-style: q = service * rho/(1-rho), rho from running totals)

PTE fills pollute the caches for radix/ECH/hugepage; NDPage bypasses; Ideal
performs no translation at all.  Huge pages use scaled-huge TLB keys and a
fragmentation model (4KB-fallback fraction grows with core count — the
contiguity-exhaustion effect the paper describes for 8 cores).

Everything is jit-compiled; states are dicts of (M, C, ...) int32 arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ndp_sim import MachineConfig
from repro.core import page_table as PT
from repro.sim import cache_model as CM

MECHS = ("radix", "ech", "hugepage", "ndpage", "ideal")
M = len(MECHS)
MAX_PTE = 4

# per-mechanism static structure.  ECH: binary (d=2) elastic cuckoo hash
# tables per Skarlatos et al. — 2 parallel probes.
N_PTE = np.array([4, 2, 3, 3, 0], np.int32)
PARALLEL = np.array([0, 1, 0, 0, 0], bool)          # ECH probes in parallel
BYPASS = np.array([0, 0, 0, 1, 0], bool)            # NDPage: PTEs skip L1
# PWC present per (mech, level): radix all 4; hugepage 3; ndpage L4/L3 only
PWC_ON = np.array([[1, 1, 1, 1],
                   [0, 0, 0, 0],
                   [1, 1, 1, 0],
                   [1, 1, 0, 0],
                   [0, 0, 0, 0]], bool)
IDEAL_IDX = 4
HUGE_IDX = 2

# 2MB huge pages: 512 x 4KB pages (footprints are unscaled)
HUGE_SHIFT = 9

# huge-page cost model (the effects the paper attributes to huge pages:
# "increased page fault latency, bloat memory footprint, and rapid
# consumption of available physical memory contiguity"):
#  - FRAC_4K: fraction of memory falling back to 4KB mappings as
#    contiguity is consumed (grows with allocating cores)
#  - HP_STALL: amortized per-access stall for 2MB fault latency /
#    compaction / bloat-induced pressure, growing with core count.
# Calibrated against Fig. 12-14 (hugepage ~= +10% at 1 core, ~0.9x radix
# at 8 cores).
FRAC_4K = {1: 0.16, 2: 0.27, 4: 0.49, 8: 0.93}
HP_STALL_BASE = 55.0
HP_STALL_PER_CORE = 7.0
QUEUE_K = 6.5               # bounded-linear queue slope (cycles at rho=1)
# ECH: elastic cuckoo tables upsize/rehash under multi-core allocation
# pressure (cuckoo-path inserts + table moves) — per-walk cost grows with
# the number of allocating cores (Skarlatos et al. §upsizing).
ECH_REHASH_QUAD = 5.0    # cost ~ (cores-2)^2: churn once headroom is gone


@dataclasses.dataclass
class SimResult:
    mechs: Tuple[str, ...]
    cycles: np.ndarray            # (M, C)
    instructions: np.ndarray      # (C,)
    trans_cycles: np.ndarray      # (M, C) translation stall cycles
    walk_cycles: np.ndarray       # (M, C)
    walks: np.ndarray             # (M, C)
    l1tlb_misses: np.ndarray      # (M, C)
    accesses: int
    pte_accesses: np.ndarray      # (M, C)
    pte_l1_hits: np.ndarray       # (M, C)
    pte_mem: np.ndarray           # (M, C)
    data_l1_misses: np.ndarray    # (M, C)
    data_mem: np.ndarray          # (M, C)

    # -- derived metrics ----------------------------------------------------
    def ipc(self) -> np.ndarray:
        return self.instructions[None, :] / self.cycles

    def speedup_vs(self, base: str = "radix") -> Dict[str, float]:
        b = self.mechs.index(base)
        mean_c = self.cycles.mean(axis=1)
        return {m: float(mean_c[b] / mean_c[i])
                for i, m in enumerate(self.mechs)}

    def avg_ptw_latency(self) -> np.ndarray:
        return (self.walk_cycles / np.maximum(self.walks, 1)).mean(axis=1)

    def translation_fraction(self) -> np.ndarray:
        return (self.trans_cycles / self.cycles).mean(axis=1)

    def tlb_miss_rate(self) -> np.ndarray:
        return (self.l1tlb_misses / self.accesses).mean(axis=1)

    def pte_l1_miss_rate(self) -> np.ndarray:
        return 1.0 - (self.pte_l1_hits
                      / np.maximum(self.pte_accesses, 1)).mean(axis=1)

    def data_l1_miss_rate(self) -> np.ndarray:
        return (self.data_l1_misses / self.accesses).mean(axis=1)


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------
def _mc(fn, mach: MachineConfig, *shape_args):
    """Broadcast a cache constructor over (M, C)."""
    proto = fn(*shape_args)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (M, mach.num_cores) + a.shape).copy(),
        proto)


def init_state(mach: MachineConfig):
    l1 = mach.l1d
    st = {
        "l1": _mc(CM.make, mach, l1.num_sets, l1.ways),
        "l1tlb": _mc(CM.make, mach, mach.l1_dtlb.entries // mach.l1_dtlb.ways,
                     mach.l1_dtlb.ways),
        "l2tlb": _mc(CM.make, mach, mach.l2_tlb.entries // 12, 12),
        # 4 per-level PWCs, 32-entry fully associative
        "pwc": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (M, mach.num_cores, MAX_PTE) + a.shape).copy(),
            CM.make(1, mach.pwc_entries)),
        "clock": jnp.zeros((M, mach.num_cores), jnp.float32),
        "mem_accs": jnp.zeros((M,), jnp.float32),
        "counters": {k: jnp.zeros((M, mach.num_cores), jnp.float32)
                     for k in ("trans", "walks", "walk_cyc", "l1tlb_miss",
                               "pte_acc", "pte_l1_hit", "pte_mem",
                               "data_l1_miss", "data_mem")},
    }
    if mach.l2 is not None:
        st["l2"] = _mc(CM.make, mach, mach.l2.num_sets, mach.l2.ways)
    if mach.l3 is not None:
        st["l3"] = _mc(CM.make, mach, mach.l3.num_sets, mach.l3.ways)
    return st


# ---------------------------------------------------------------------------
# the per-step model
# ---------------------------------------------------------------------------
def _make_step(mach: MachineConfig):
    is_cpu = mach.l2 is not None
    mem_lat = float(mach.mem_latency)
    service = float(mach.mem_service)
    l1_lat = float(mach.l1d.latency)
    l2tlb_lat = float(mach.l2_tlb.latency)
    pwc_lat = float(mach.pwc_latency)
    l2_lat = float(mach.l2.latency) if mach.l2 else 0.0
    l3_lat = float(mach.l3.latency) if mach.l3 else 0.0
    promo = HP_STALL_BASE + HP_STALL_PER_CORE * max(mach.num_cores - 1, 0)
    ech_rehash = ECH_REHASH_QUAD * max(mach.num_cores - 2, 0) ** 2

    n_pte = jnp.asarray(N_PTE)
    parallel = jnp.asarray(PARALLEL)
    bypass = jnp.asarray(BYPASS)
    pwc_on = jnp.asarray(PWC_ON)
    mech_ids = jnp.arange(M)

    def mem_path(caches, line, q, *, is_pte, bypass_l1, enabled):
        """One access through the hierarchy. Returns (caches, latency,
        l1_hit, went_mem).  PTE fills insert (pollute) unless bypassed."""
        do_cache = enabled & ~bypass_l1
        l1, l1_hit = CM.access(caches["l1"], line, insert=do_cache,
                               enabled=do_cache)
        caches = dict(caches, l1=l1)
        if is_cpu:
            need2 = do_cache & ~l1_hit
            l2, l2_hit = CM.access(caches["l2"], line, insert=need2,
                                   enabled=need2)
            need3 = need2 & ~l2_hit
            l3, l3_hit = CM.access(caches["l3"], line, insert=need3,
                                   enabled=need3)
            caches = dict(caches, l2=l2, l3=l3)
            went_mem = (need3 & ~l3_hit) | (enabled & bypass_l1)
            lat = jnp.where(
                l1_hit, l1_lat,
                jnp.where(l2_hit, l1_lat + l2_lat,
                          jnp.where(l3_hit, l1_lat + l2_lat + l3_lat,
                                    l1_lat + l2_lat + l3_lat + mem_lat + q)))
            lat = jnp.where(enabled & bypass_l1, mem_lat + q, lat)
        else:
            went_mem = (do_cache & ~l1_hit) | (enabled & bypass_l1)
            lat = jnp.where(l1_hit, l1_lat, l1_lat + mem_lat + q)
            lat = jnp.where(enabled & bypass_l1, mem_lat + q, lat)
        lat = jnp.where(enabled, lat, 0.0)
        return caches, lat, l1_hit & enabled, went_mem & enabled

    def per_mech_core(sub, vpn, off, work, pte_lines, is4k, q, mech):
        """sub: state slice for one (mech, core). Returns (sub, metrics)."""
        cnt = {}
        ideal = mech == IDEAL_IDX
        huge = mech == HUGE_IDX

        # ---- TLB ----
        tlb_key = jnp.where(huge & ~is4k,
                            (vpn >> HUGE_SHIFT) | (1 << 26), vpn)
        l1tlb, l1_hit = CM.access(sub["l1tlb"], tlb_key,
                                  insert=jnp.asarray(True),
                                  enabled=~ideal)
        l2tlb, l2_hit = CM.access(sub["l2tlb"], tlb_key,
                                  insert=jnp.asarray(True),
                                  enabled=~ideal & ~l1_hit)
        sub = dict(sub, l1tlb=l1tlb, l2tlb=l2tlb)
        walk = ~ideal & ~l1_hit & ~l2_hit
        cnt["l1tlb_miss"] = (~ideal & ~l1_hit).astype(jnp.float32)
        cnt["walks"] = walk.astype(jnp.float32)

        # ---- page-table walk ----
        # hugepage 4KB-fallback regions walk like radix (4 levels)
        eff_n = jnp.where(huge & is4k, 4, n_pte[mech])
        is_par = parallel[mech]
        byp = bypass[mech]
        walk_cyc = jnp.zeros((), jnp.float32)
        par_max = jnp.zeros((), jnp.float32)
        pte_acc = jnp.zeros((), jnp.float32)
        pte_l1h = jnp.zeros((), jnp.float32)
        pte_mem_n = jnp.zeros((), jnp.float32)
        caches = sub
        pwc = sub["pwc"]
        for lvl in range(MAX_PTE):
            en = walk & (lvl < eff_n)
            line = pte_lines[lvl]
            use_pwc = en & pwc_on[mech, lvl]
            pwc_lvl = jax.tree.map(lambda a: a[lvl], pwc)
            pwc_new, pwc_hit = CM.access(pwc_lvl, line,
                                         insert=jnp.asarray(True),
                                         enabled=use_pwc)
            pwc = jax.tree.map(lambda full, new: full.at[lvl].set(new),
                               pwc, pwc_new)
            need_mem_path = en & ~pwc_hit
            caches, lat, p_l1h, p_mem = mem_path(
                caches, line, q, is_pte=True,
                bypass_l1=byp & need_mem_path, enabled=need_mem_path)
            lvl_lat = jnp.where(pwc_hit, pwc_lat, lat)
            lvl_lat = jnp.where(en, lvl_lat, 0.0)
            walk_cyc = walk_cyc + jnp.where(is_par, 0.0, lvl_lat)
            par_max = jnp.maximum(par_max, lvl_lat)
            pte_acc += need_mem_path.astype(jnp.float32)
            pte_l1h += p_l1h.astype(jnp.float32)
            pte_mem_n += p_mem.astype(jnp.float32)
        # parallel (ECH) walks: all probes issue simultaneously and the walk
        # completes when the HITTING probe returns — one memory-access
        # latency plus own-bank conflict + issue overhead.  The extra
        # probes only add traffic (counted in pte_mem -> queue pressure).
        # Multi-core: amortized cuckoo upsizing/rehash contention.
        walk_cyc = jnp.where(is_par, par_max + 2.0 + ech_rehash, walk_cyc)
        sub = dict(caches, pwc=pwc)

        trans = jnp.where(l1_hit | ideal, 0.0,
                          l2tlb_lat + jnp.where(walk, walk_cyc, 0.0))
        trans = trans + jnp.where(huge, promo, 0.0)
        cnt["walk_cyc"] = jnp.where(walk, walk_cyc, 0.0)
        cnt["pte_acc"] = pte_acc
        cnt["pte_l1_hit"] = pte_l1h
        cnt["pte_mem"] = pte_mem_n
        cnt["trans"] = trans

        # ---- data access ----
        data_line = vpn * 64 + off
        sub2, dlat, d_l1h, d_mem = mem_path(
            sub, data_line, q, is_pte=False,
            bypass_l1=jnp.asarray(False), enabled=jnp.asarray(True))
        cnt["data_l1_miss"] = (~d_l1h).astype(jnp.float32)
        cnt["data_mem"] = d_mem.astype(jnp.float32)

        step_cycles = work.astype(jnp.float32) + 1.0 + trans + (
            dlat - l1_lat)
        mem_n = pte_mem_n + d_mem.astype(jnp.float32)
        return sub2, step_cycles, cnt, mem_n

    vmapped = jax.vmap(                       # over cores
        jax.vmap(per_mech_core,               # over mechanisms
                 in_axes=(0, None, None, None, 0, None, 0, 0)),
        in_axes=(1, 0, 0, 0, 0, 0, None, None), out_axes=1)
    # axes: state dicts have (M, C, ...) -> vmap C (axis 1) then M (axis 0)

    def step(carry, x):
        state = carry
        vpn, off, work, pte_lines, is4k = x
        # queue delay from aggregate measured memory demand (per mech).
        # Bounded-linear law: banked DRAM degrades gently up to saturation
        # (an M/M/1 knee over-penalizes small traffic deltas at high load).
        elapsed = jnp.maximum(state["clock"].mean(axis=1), 1.0)   # (M,)
        rate = state["mem_accs"] / elapsed        # aggregate accesses/cycle
        rho = jnp.clip(rate * service, 0.0, 0.96)
        q = service * rho * QUEUE_K                                # (M,)

        caches = {k: state[k] for k in state
                  if k not in ("clock", "mem_accs", "counters")}
        new_caches, cyc, cnt, mem_n = vmapped(
            caches, vpn, off, work, pte_lines, is4k, q, jnp.arange(M))
        new_state = dict(new_caches)
        new_state["clock"] = state["clock"] + cyc
        new_state["mem_accs"] = state["mem_accs"] + mem_n.sum(axis=1)
        new_state["counters"] = {
            k: state["counters"][k] + cnt[k] for k in state["counters"]}
        return new_state, None

    return step


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(0,))
def _run(mach: MachineConfig, xs):
    state = init_state(mach)
    step = _make_step(mach)
    state, _ = jax.lax.scan(step, state, xs)
    return state


def simulate(mach: MachineConfig, trace: Dict[str, np.ndarray],
             length: int | None = None) -> SimResult:
    """Run all 5 mechanisms over a multi-core trace on ``mach``."""
    vpn = trace["vpn"][:, :length] if length else trace["vpn"]
    off = trace["off"][:, : vpn.shape[1]]
    work = trace["work"][:, : vpn.shape[1]]
    c, t = vpn.shape
    assert c == mach.num_cores, (c, mach.num_cores)

    # precompute PTE lines per mechanism: (T, C, M, 4)
    vj = jnp.asarray(vpn.T)                       # (T, C)
    walks = {
        "radix": PT.radix4_walk_lines(vj),
        "ech": ech_pad(PT.ech_probe_lines(vj)),
        "hugepage": ech_pad(PT.hugepage_walk_lines(vj)),
        "ndpage": ech_pad(PT.ndpage_walk_lines(vj)),
    }
    # hugepage 4KB-fallback regions ALSO need radix lines; reuse radix's
    pte = jnp.stack([walks["radix"], walks["ech"], walks["hugepage"],
                     walks["ndpage"], jnp.zeros_like(walks["radix"])],
                    axis=2)                       # (T, C, M, 4)
    # hugepage fallback pages: where is4k, walk radix lines
    frac = FRAC_4K.get(mach.num_cores, min(0.93, 0.05 + 0.11 *
                                           mach.num_cores))
    region = vpn >> HUGE_SHIFT
    is4k_np = (_hash_np(region) % 1000) < int(frac * 1000)
    is4k = jnp.asarray(is4k_np.T)                 # (T, C)
    pte = pte.at[:, :, HUGE_IDX, :].set(
        jnp.where(is4k[..., None], walks["radix"], pte[:, :, HUGE_IDX, :]))

    xs = (vj.astype(jnp.int32), jnp.asarray(off.T), jnp.asarray(work.T),
          pte.astype(jnp.int32), is4k)
    state = jax.block_until_ready(_run(mach, xs))

    cnt = {k: np.asarray(v) for k, v in state["counters"].items()}
    return SimResult(
        mechs=MECHS,
        cycles=np.asarray(state["clock"]),
        instructions=np.asarray((work + 1).sum(axis=1), np.float64),
        trans_cycles=cnt["trans"],
        walk_cycles=cnt["walk_cyc"],
        walks=cnt["walks"],
        l1tlb_misses=cnt["l1tlb_miss"],
        accesses=t,
        pte_accesses=cnt["pte_acc"],
        pte_l1_hits=cnt["pte_l1_hit"],
        pte_mem=cnt["pte_mem"],
        data_l1_misses=cnt["data_l1_miss"],
        data_mem=cnt["data_mem"],
    )


def ech_pad(a: jnp.ndarray) -> jnp.ndarray:
    """Pad (T, C, 3) walk lines to (T, C, 4)."""
    pad = [(0, 0)] * (a.ndim - 1) + [(0, MAX_PTE - a.shape[-1])]
    return jnp.pad(a, pad)


def _hash_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) ^ np.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)
