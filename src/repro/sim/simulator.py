"""Trace-driven timing simulator for NDP/CPU address translation.

Mechanistic interval model (Sniper-style): every trace entry is one memory
instruction preceded by ``work`` non-memory instructions.  Per entry we
model, for all mechanisms at once (leading M axis) and all cores (C axis):

  1. L1 DTLB lookup (free on hit) -> L2 TLB (12cy) -> page-table walk
  2. the walk's PTE accesses: per-level PWC, then cache hierarchy or —
     for NDPage — a direct memory access (L1 bypass), serial for
     radix/hugepage/ndpage, parallel (max) for ECH
  3. the data access through the cache hierarchy
  4. a shared-memory queueing delay from aggregate measured demand
     (bounded-linear: q = service * rho * K, rho from running totals)

PTE fills pollute the caches for radix/ECH/hugepage; NDPage bypasses; Ideal
performs no translation at all.  Huge pages use scaled-huge TLB keys and a
fragmentation model (4KB-fallback fraction grows with core count — the
contiguity-exhaustion effect the paper describes for 8 cores).

Which mechanisms run, and their static structure (walk depth, parallel
probes, L1 bypass, PWC placement, huge-page semantics), comes from the
declarative spec registry in :mod:`repro.sim.mechanisms` — adding a
mechanism there is all it takes to simulate it.

Engine
------
A chunked ``jax.lax.scan``, split along the only real serial dependency:

* the **scan** carries nothing but the LRU tag/stamp tables and performs
  the cache/TLB/PWC lookups (the state evolution that must be
  sequential), emitting one packed int32 of hit bits per (mech, core)
  per entry;
* a vectorized **epilogue** (same jit) expands the hit bits over the
  whole chunk at once and does every latency/counter computation there —
  the per-step graph stays tiny, which is what per-op-overhead-bound CPU
  backends need.

The trace is pre-generated, padded to fixed-shape chunks, and streamed
through ONE jitted runner whose state buffers are donated between chunks.
The runner is compiled once per (machine SHAPE, mechanism walk-fn tuple,
chunk length) — trace length never retriggers compilation, and neither
does any value-like machine parameter: :class:`MachineShape` captures
only what determines array shapes (core count, table geometries), while
latencies/service times (:func:`_data_params`) and the per-mechanism
flag tables (:func:`_mech_arrays`) enter the jit as plain operands.
That split is what makes parameter sweeps cheap — a grid over memory
latency or the L1-bypass flag reuses one compiled runner, with the
varying values riding the batch lanes as data (see
:mod:`repro.sim._sweep`).  The queueing delay is held constant within a
chunk (recomputed from aggregate demand at every chunk boundary), which
is what makes the split exact.

Batch axis
----------
:func:`simulate_batch` adds a batch axis over B *independent
simulations* that share one ``MachineConfig`` shape (e.g. all Table-II
workloads at a given machine × core count): the whole bucket runs as ONE
chunked-scan dispatch.  LRU tables are laid out ``(B, C, M, sets,
ways)`` — every mapped axis stays leading, so no per-step transpose is
ever materialized (the same rule that drove the (C, M) layout) — and
are reshaped (free: the leading axes are contiguous) onto the fused
``(B*C, M, sets, ways)`` lane layout at dispatch: independent sims are
exactly the proven two-level engine with a wider lane axis, which
XLA-CPU runs at full width, whereas a literal third vmap level regresses
the per-step gathers ~2x.  Per-sim queue windows, valid masks (lanes may
have different true trace lengths), and counters stay per-sim and are
sliced back into per-sim :class:`SimResult` objects at the end; results
are bit-exact vs per-sim :func:`simulate` — lanes never interact.
When more than one XLA host device is available (opt-in via
``SIM_DEVICES=N`` before process start, which forces
``--xla_force_host_platform_device_count``), the B axis is sharded
across devices with ``jax.sharding`` — lanes never communicate, so the
fleet parallelizes embarrassingly.

:func:`simulate_batch_varied` generalizes the lanes to heterogeneous
jobs: every lane carries its own ``MachineConfig`` *values* and its own
mechanism-table *values* (the shape half must match — that is the
bucket invariant the sweep engine enforces), so one dispatch can cover
a whole sensitivity grid over latencies, bypass flags, or huge-page
knobs.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.ndp_sim import MachineConfig
from repro.core import page_table as PT
from repro.sim import mechanisms as _mechanisms
from repro.sim import memory_model as MM
from repro.sim.mechanisms import (DEFAULT_MECHS, MAX_PTE, specs_for,
                                  tables_for)

MECHS = DEFAULT_MECHS
M = len(MECHS)

#: scan-chunk length; traces are padded to a multiple of this so one
#: compiled runner serves every trace length
DEFAULT_CHUNK = 512

# 2MB huge pages: 512 x 4KB pages (footprints are unscaled)
HUGE_SHIFT = 9

# huge-page cost model (the effects the paper attributes to huge pages:
# "increased page fault latency, bloat memory footprint, and rapid
# consumption of available physical memory contiguity"):
#  - FRAC_4K: fraction of memory falling back to 4KB mappings as
#    contiguity is consumed (grows with allocating cores)
#  - HP_STALL: amortized per-access stall for 2MB fault latency /
#    compaction / bloat-induced pressure, growing with core count.
# Calibrated against Fig. 12-14 (hugepage ~= +10% at 1 core, ~0.9x radix
# at 8 cores).
FRAC_4K = {1: 0.16, 2: 0.27, 4: 0.49, 8: 0.93}
HP_STALL_BASE = 55.0
HP_STALL_PER_CORE = 7.0
QUEUE_K = MM.QUEUE_K        # bounded-linear queue slope (cycles at rho=1)
# ECH: elastic cuckoo tables upsize/rehash under multi-core allocation
# pressure (cuckoo-path inserts + table moves) — per-walk cost grows with
# the number of allocating cores (Skarlatos et al. §upsizing).
ECH_REHASH_QUAD = 5.0    # cost ~ (cores-2)^2: churn once headroom is gone

_INT_MIN = jnp.iinfo(jnp.int32).min


@dataclasses.dataclass
class SimResult:
    mechs: Tuple[str, ...]
    cycles: np.ndarray            # (M, C)
    instructions: np.ndarray      # (C,)
    trans_cycles: np.ndarray      # (M, C) translation stall cycles
    walk_cycles: np.ndarray       # (M, C)
    walks: np.ndarray             # (M, C)
    l1tlb_misses: np.ndarray      # (M, C)
    accesses: int
    pte_accesses: np.ndarray      # (M, C)
    pte_l1_hits: np.ndarray       # (M, C)
    pte_mem: np.ndarray           # (M, C)
    data_l1_misses: np.ndarray    # (M, C)
    data_mem: np.ndarray          # (M, C)

    # -- derived metrics ----------------------------------------------------
    def ipc(self) -> np.ndarray:
        return self.instructions[None, :] / self.cycles

    def speedup_vs(self, base: str = "radix") -> Dict[str, float]:
        b = self.mechs.index(base)
        mean_c = self.cycles.mean(axis=1)
        return {m: float(mean_c[b] / mean_c[i])
                for i, m in enumerate(self.mechs)}

    def avg_ptw_latency(self) -> np.ndarray:
        return (self.walk_cycles / np.maximum(self.walks, 1)).mean(axis=1)

    def translation_fraction(self) -> np.ndarray:
        return (self.trans_cycles / self.cycles).mean(axis=1)

    def tlb_miss_rate(self) -> np.ndarray:
        return (self.l1tlb_misses / self.accesses).mean(axis=1)

    def pte_l1_miss_rate(self) -> np.ndarray:
        return 1.0 - (self.pte_l1_hits
                      / np.maximum(self.pte_accesses, 1)).mean(axis=1)

    def data_l1_miss_rate(self) -> np.ndarray:
        return (self.data_l1_misses / self.accesses).mean(axis=1)

    # -- slicing helpers ----------------------------------------------------
    def select(self, mechs: Sequence[str] | str | None = None,
               cores: Sequence[int] | slice | int | None = None
               ) -> "SimResult":
        """Sub-view of the result restricted to ``mechs`` (names, order
        preserved as given) and/or ``cores`` (index/slice/sequence) — the
        figure code uses this instead of raw positional numpy indexing."""
        if isinstance(mechs, str):
            mechs = (mechs,)
        names = self.mechs if mechs is None else tuple(mechs)
        mi = np.asarray([self.mechs.index(n) for n in names])
        if cores is None:
            ci = np.arange(self.cycles.shape[1])
        elif isinstance(cores, slice):
            ci = np.arange(self.cycles.shape[1])[cores]
        else:
            ci = np.atleast_1d(np.asarray(cores))
        mc = lambda a: a[np.ix_(mi, ci)]                     # noqa: E731
        return SimResult(
            mechs=names,
            cycles=mc(self.cycles),
            instructions=self.instructions[ci],
            trans_cycles=mc(self.trans_cycles),
            walk_cycles=mc(self.walk_cycles),
            walks=mc(self.walks),
            l1tlb_misses=mc(self.l1tlb_misses),
            accesses=self.accesses,
            pte_accesses=mc(self.pte_accesses),
            pte_l1_hits=mc(self.pte_l1_hits),
            pte_mem=mc(self.pte_mem),
            data_l1_misses=mc(self.data_l1_misses),
            data_mem=mc(self.data_mem),
        )

    def scalar(self, metric: str, mech: str) -> float:
        """One derived metric for one mechanism, as a plain float:
        ``res.scalar("avg_ptw_latency", "radix")``."""
        return getattr(self.select(mechs=(mech,)), metric)().item()


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------
def _table_shapes(mach: MachineConfig) -> Dict[str, Tuple[int, int]]:
    """name -> (num_sets, ways) for every LRU table of one (mech, core)."""
    shapes = {
        "l1": (mach.l1d.num_sets, mach.l1d.ways),
        "l1tlb": (mach.l1_dtlb.entries // mach.l1_dtlb.ways,
                  mach.l1_dtlb.ways),
        "l2tlb": (mach.l2_tlb.entries // 12, 12),
        # per-level PWCs as one table: set index IS the walk level
        "pwc": (MAX_PTE, mach.pwc_entries),
    }
    if mach.l2 is not None:
        shapes["l2"] = (mach.l2.num_sets, mach.l2.ways)
    if mach.l3 is not None:
        shapes["l3"] = (mach.l3.num_sets, mach.l3.ways)
    if mach.ctlb_kb > 0:
        # Victima cache-as-TLB: ctlb_kb KB of repurposed cache capacity,
        # one translation per 64B line -> entries = capacity / line.
        # Structurally ABSENT at ctlb_kb=0, so default machines keep
        # their exact compiled graphs (and bit-exact results).
        entries = mach.ctlb_kb * 1024 // 64
        shapes["ctlb"] = (max(entries // mach.ctlb_ways, 1),
                          mach.ctlb_ways)
    return shapes


# ---------------------------------------------------------------------------
# the shape/data split: what compiles vs what rides along as operands
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MachineShape:
    """Everything about a ``MachineConfig`` that determines ARRAY SHAPES
    in the compiled runner: the core count, the (sets, ways) geometry
    of every LRU table, and the memory model's SHAPE half (kind + bank
    geometry — a banked machine carries per-bank row state and five
    extra hit bits).  Two configs with equal shape (and the same
    mechanism walk functions) share one compiled runner — their
    remaining differences (latencies, memory service/row timings,
    huge-page stalls, per-mechanism flags) are plain jit operands.
    Hashable on purpose: this IS the runner-cache key."""

    num_cores: int
    tables: Tuple[Tuple[str, int, int], ...]    # (name, sets, ways)
    #: MemoryModel.shape_key(): ("bounded_linear",) for every bounded
    #: machine — the banked geometry fields are inert there — or
    #: ("banked", num_banks, row_buffer_bytes)
    memory: Tuple = ("bounded_linear",)

    @property
    def hier(self) -> Tuple[str, ...]:
        names = {n for n, _, _ in self.tables}
        return ("l1", "l2", "l3") if "l2" in names else ("l1",)


def machine_shape(mach: MachineConfig) -> MachineShape:
    return MachineShape(
        num_cores=mach.num_cores,
        tables=tuple((n, s, w)
                     for n, (s, w) in _table_shapes(mach).items()),
        memory=mach.memory.shape_key())


def _shape_tables(shape: MachineShape) -> Dict[str, Tuple[int, int]]:
    return {n: (s, w) for n, s, w in shape.tables}


def _data_params(mach: MachineConfig) -> Dict[str, np.float32]:
    """The value-like half of a ``MachineConfig``: every latency the
    timing epilogue consumes, as numpy scalars (NOT Python floats —
    weak-typed constants would bake into the compiled graph and defeat
    the shape/data split).  Memory timing comes from the MemoryModel:
    ``mem_lat`` is the closed-row/full access latency, ``row_save`` the
    precharge+activate cycles an open-row hit skips (0.0 for
    bounded_linear — the key exists either way so dp pytrees always
    match), ``service`` the aggregate (bounded) or per-bank (banked)
    queue service time."""
    return {k: np.float32(v) for k, v in {
        "mem_lat": mach.memory.miss_latency(),
        "row_save": mach.memory.row_hit_save(),
        "l1_lat": mach.l1d.latency,
        "l2_lat": mach.l2.latency if mach.l2 else 0.0,
        "l3_lat": mach.l3.latency if mach.l3 else 0.0,
        "l2tlb_lat": mach.l2_tlb.latency,
        "pwc_lat": mach.pwc_latency,
        "service": mach.memory.service,
        "promo": (HP_STALL_BASE
                  + HP_STALL_PER_CORE * max(mach.num_cores - 1, 0)),
        "ech_rehash": ECH_REHASH_QUAD * max(mach.num_cores - 2, 0) ** 2,
        "ctlb_lat": mach.ctlb_latency,
        # multi-stack NDP memory: the expected extra hop cost of a
        # memory access, (remote fraction) x (hop cycles).  Exactly 0.0
        # at num_stacks=1, keeping single-stack machines bit-exact.
        "stack_pen": ((1.0 - 1.0 / mach.num_stacks)
                      * mach.stack_hop_cycles),
    }.items()}


def _mech_arrays(names: Tuple[str, ...]) -> Dict[str, np.ndarray]:
    """The spec registry lowered to per-mechanism VALUE arrays — jit
    operands as well, so lanes of one dispatch may disagree on walk
    depth, bypass, PWC placement, or huge-page semantics.  Only the
    walk-line FUNCTIONS (:func:`_walk_fns`) stay static."""
    t = tables_for(names)
    return {"n_pte": t.n_pte, "parallel": t.parallel, "bypass": t.bypass,
            "pwc_on": t.pwc_on, "huge": t.huge, "ideal": t.ideal,
            "cache_tlb": t.cache_tlb, "segment": t.segment,
            "colocate": t.colocate}


def _walk_fns(names: Tuple[str, ...]) -> Tuple:
    """The static (code, not data) half of a mechanism tuple: the
    VPN -> PTE-line functions, part of the runner-cache key."""
    return tuple(s.walk_fn for s in specs_for(names))


#: misses accumulated before explicit clear_runner_cache() calls, so
#: runner_cache_info().misses stays MONOTONE across watchdog recoveries
#: (lru_cache.cache_clear resets its own counters)
_CLEARED_MISSES = 0


def runner_cache_info():
    """Cache stats of the compiled-runner cache: ``misses`` counts the
    runners built this process — one per distinct (machine shape,
    walk-fn tuple, chunk, batched) combination, monotone across
    :func:`clear_runner_cache`.  The sweep engine and its tests use
    this to assert "one compile per shape bucket"."""
    info = _chunk_runner.cache_info()
    return info._replace(misses=info.misses + _CLEARED_MISSES)


def clear_runner_cache() -> None:
    """Drop every cached compiled runner.  The watchdog's recovery
    hook: after a hung/timed-out dispatch the wedged executable is the
    prime suspect, so the retry rebuilds it from scratch (the
    persistent .jax_cache still serves unaffected compilations).
    Compile accounting survives: past misses fold into the monotone
    counter :func:`runner_cache_info` reports."""
    global _CLEARED_MISSES
    _CLEARED_MISSES += _chunk_runner.cache_info().misses
    _chunk_runner.cache_clear()


def init_state(mach: MachineConfig, m: int = M, batch: int | None = None):
    c = mach.num_cores
    # batch=None: one simulation, tables (C, M, sets, ways).  batch=B:
    # B independent sims, tables (B, C, M, sets, ways).  Either way every
    # vmap level maps axis 0 with axis-0 outputs, so no per-step
    # transpose (= full table copy) is ever materialized.  Public results
    # stay (M, C) per sim.
    lead = () if batch is None else (batch,)

    def table(sets, ways):
        return {"tags": jnp.zeros(lead + (c, m, sets, ways), jnp.int32),
                "lru": jnp.zeros(lead + (c, m, sets, ways), jnp.int32)}

    st = {name: table(*shape) for name, shape in _table_shapes(mach).items()}
    st["stamp"] = jnp.zeros(lead + (c, m), jnp.int32)
    st["clock"] = jnp.zeros(lead + (m, c), jnp.float32)
    if mach.memory.kind == "banked":
        # per-bank open-row ids (rides the scan carry like the LRU
        # tables; -1 = all rows closed) and per-bank access totals for
        # the per-bank queue windows
        st["bank_row"] = jnp.full(lead + (c, m, mach.memory.num_banks),
                                  -1, jnp.int32)
        st["mem_accs"] = jnp.zeros(lead + (m, mach.memory.num_banks),
                                   jnp.float32)
    else:
        st["mem_accs"] = jnp.zeros(lead + (m,), jnp.float32)
    st["counters"] = {k: jnp.zeros(lead + (m, c), jnp.float32)
                      for k in ("trans", "walks", "walk_cyc", "l1tlb_miss",
                                "pte_acc", "pte_l1_hit", "pte_mem",
                                "data_l1_miss", "data_mem")}
    return st


# ---------------------------------------------------------------------------
# the model: sequential hit extraction + vectorized timing
# ---------------------------------------------------------------------------
def _build_model(shape: MachineShape, batched: bool = False):
    """The model, parameterized ONLY by shape: every latency and every
    per-mechanism flag arrives at trace time as an operand (``dp`` data
    params / ``mt`` mechanism tables), so one build serves a whole
    sensitivity grid.  In the batched engine both may carry a leading
    lane axis — lanes of one dispatch can simulate different machines
    and mechanism variants."""
    hier = shape.hier
    shapes = _shape_tables(shape)
    has_ctlb = "ctlb" in shapes
    has_banked = shape.memory[0] == "banked"
    if has_banked:
        n_banks = int(shape.memory[1])
        lines_per_row = int(shape.memory[2]) // MM.LINE_BYTES

    # hit-bit layout of the packed per-entry int32
    #   0: l1tlb  1: l2tlb  2..5: pwc level  6+5*h..10+5*h: hierarchy
    #   level h hits for [pte0..pte3, data]; when the machine HAS a
    #   cache-as-TLB its hit bit is APPENDED after everything else so
    #   pre-existing bit indices (and values) never move; a banked
    #   memory likewise APPENDS five row-buffer-hit bits (one per line
    #   site) after that.  Worst case 6 + 15 + 1 + 5 = 27 <= 31.
    ctlb_bit = 6 + 5 * len(hier)
    bank_bit = ctlb_bit + (1 if has_ctlb else 0)
    n_bits = bank_bit + (5 if has_banked else 0)
    assert n_bits <= 31

    # LRU stamp slots: every access site gets a fixed offset so one scalar
    # stamp per (mech, core) serves all tables with program-order ties;
    # the ctlb slot is likewise appended at the end
    n_slots = 2 + MAX_PTE + 5 * len(hier) + (1 if has_ctlb else 0)
    ctlb_slot = 2 + MAX_PTE + 5 * len(hier)

    def access(tab, sets, key, en, stamp, *, set_override=None):
        """One scalar LRU lookup+fill.  Scalar set index keeps XLA on the
        dynamic-slice fast path — this is the per-step hot loop."""
        num_sets, _ = sets
        if set_override is None:
            s = jax.lax.rem(key, num_sets)
            tag = jax.lax.div(key, num_sets) + 1
        else:
            s = set_override                        # pwc: set = walk level
            tag = key + 1
        row_tags = tab["tags"][s]
        row_lru = tab["lru"][s]
        match = row_tags == tag
        hit = match.any() & en
        # a match wins the argmin outright; otherwise it picks true LRU
        way = jnp.argmin(jnp.where(match, _INT_MIN, row_lru))
        s_safe = jnp.where(en, s, num_sets)         # disabled -> dropped
        new = {"tags": tab["tags"].at[s_safe, way].set(tag, mode="drop"),
               "lru": tab["lru"].at[s_safe, way].set(stamp, mode="drop")}
        return new, hit

    def per_mc(sub, stamp, vpn, off, pte_lines, is4k, valid, mt):
        """Hit extraction for one (mech, core): touches every table once
        per gated access site, returns the packed hit bits.  ``mt`` is
        this mechanism's scalar flag/depth values (vmapped off the M —
        and, batched, the lane — axis of the mechanism tables)."""
        ideal = mt["ideal"]
        huge = mt["huge"]
        byp = mt["bypass"]

        tlb_key = jnp.where(huge & ~is4k,
                            (vpn >> HUGE_SHIFT) | (1 << 26), vpn)
        # direct-segment mechanisms translate in-segment accesses (the
        # non-fragmented share, ~is4k) via base/limit registers: no TLB
        # lookup, no walk — only the fragmentation-broken rest enters
        # the translation machinery below
        en0 = valid & ~ideal & ~(mt["segment"] & ~is4k)
        sub["l1tlb"], h_l1tlb = access(sub["l1tlb"], shapes["l1tlb"],
                                       tlb_key, en0, stamp)
        en1 = en0 & ~h_l1tlb
        sub["l2tlb"], h_l2tlb = access(sub["l2tlb"], shapes["l2tlb"],
                                       tlb_key, en1, stamp + 1)
        walk = en1 & ~h_l2tlb
        if has_ctlb:
            # cache-as-TLB probe after an L2-TLB miss: a hit short-
            # circuits the walk for cache_tlb mechanisms
            en_ct = walk & mt["cache_tlb"]
            sub["ctlb"], h_ctlb = access(sub["ctlb"], shapes["ctlb"],
                                         tlb_key, en_ct,
                                         stamp + ctlb_slot)
            walk = walk & ~h_ctlb

        # hugepage 4KB-fallback regions walk like radix (4 levels)
        eff_n = jnp.where(huge & is4k, MAX_PTE, mt["n_pte"])
        bits = [h_l1tlb, h_l2tlb]
        pwc_hits = []
        for lvl in range(MAX_PTE):
            en = walk & (lvl < eff_n) & mt["pwc_on"][lvl]
            sub["pwc"], h = access(sub["pwc"], shapes["pwc"],
                                   pte_lines[lvl], en, stamp + 2 + lvl,
                                   set_override=lvl)
            pwc_hits.append(h)
            bits.append(h)

        data_line = vpn * 64 + off
        lines = [pte_lines[lvl] for lvl in range(MAX_PTE)] + [data_line]
        # enables at the top of the hierarchy; lower levels chain on miss
        ens = [walk & (lvl < eff_n) & ~pwc_hits[lvl] & ~byp
               for lvl in range(MAX_PTE)] + [valid]
        for h_i, name in enumerate(hier):
            slot = stamp + 2 + MAX_PTE + 5 * h_i
            nxt = []
            for i in range(5):
                sub[name], h = access(sub[name], shapes[name], lines[i],
                                      ens[i], slot + i)
                nxt.append(ens[i] & ~h)
                bits.append(h)
            ens = nxt

        if has_ctlb:
            bits.append(h_ctlb)          # appended: old bit indices keep
        if has_banked:
            # DRAM row-buffer tracking: one open-row id per bank rides
            # the carry like the LRU tables.  Only accesses that
            # actually reach memory touch a bank — bypassed PTE lines
            # go straight there, everything else is the post-hierarchy
            # miss chain (``ens`` after the loop above).  Sites update
            # in program order (pte0..pte3, then data).
            mem_ens = [(walk & (lvl < eff_n) & ~pwc_hits[lvl] & byp)
                       | ens[lvl] for lvl in range(MAX_PTE)]
            mem_ens.append(ens[MAX_PTE])
            rows = sub["bank_row"]
            for i in range(5):
                bk = jax.lax.rem(jax.lax.div(lines[i], lines_per_row),
                                 n_banks)
                rw = jax.lax.div(lines[i], lines_per_row * n_banks)
                bits.append((rows[bk] == rw) & mem_ens[i])
                rows = rows.at[jnp.where(mem_ens[i], bk, n_banks)].set(
                    rw, mode="drop")
            sub["bank_row"] = rows
        packed = (jnp.stack(bits)
                  * (1 << jnp.arange(n_bits, dtype=jnp.int32))).sum()
        return sub, stamp + n_slots, packed

    # inner vmap over mechanisms, outer over cores — every mapped input
    # and output uses axis 0 so XLA never transposes the carried tables.
    # The batched variant serves the B (independent-simulation) axis
    # FUSED into the core axis: lanes are fully independent either way,
    # and a wider leading axis is the layout XLA-CPU already handles
    # well, whereas a literal third vmap level regresses the per-step
    # gathers.  ``valid`` and the mechanism tables change: per-sim trace
    # lengths and per-sim mechanism values make them per-lane inputs.
    per_core = jax.vmap(per_mc,
                        in_axes=(0, 0, None, None, 0, None, None, 0))
    full = jax.vmap(per_core,
                    in_axes=(0, 0, 0, 0, 0, 0, None, None))
    full_v = jax.vmap(per_core,
                      in_axes=(0, 0, 0, 0, 0, 0, 0, 0))

    def make_step(mt):
        def step(carry, x):
            sub, stamp = carry
            vpn, off, pte_lines, is4k, valid = x
            fn = full_v if batched else full
            sub, stamp, packed = fn(sub, stamp, vpn, off, pte_lines,
                                    is4k, valid, mt)
            return (sub, stamp), packed
        return step

    def epilogue(packed, work, is4k, valid, q, mt, dp, lines=None):
        """Vectorized timing over the whole chunk.

        packed: (T, M, C) hit bits; work/is4k: (T, C); valid: (T,) — or
        (T, C) per-lane in the batched engine, where C is the fused
        B*cores axis; q: (M,) queue delay — (M, C) when batched (per-sim
        windows expanded per lane) — constant within the chunk.  Banked
        memory generalizes q to a trailing bank axis ((M, banks) /
        (M, C, banks)) and passes ``lines`` (T, M, C, 5), the line ids
        of the five access sites, so each access gathers ITS bank's
        queue window and row-hit discount.
        ``mt`` mechanism tables ((M,) leaves, or (C, M) per lane) and
        ``dp`` data params (scalars, or (C,) per lane) are operands.
        Re-derives the same gates the scan used (pure functions of the
        hit bits) and produces the (M, C) counter/clock deltas.
        """
        def bit(i):
            return ((packed >> i) & 1).astype(bool)

        def mb(a):          # mech table -> broadcast over (T, M, C)
            return a[None, :, None] if a.ndim == 1 else a.T[None]

        def d3(v):          # data param -> broadcast over (T, M, C)
            return v if v.ndim == 0 else v[None, None, :]

        def d4(v):          # data param -> broadcast over (T, M, C, 5)
            return v if v.ndim == 0 else v[None, None, :, None]

        validb = (valid[:, None, None] if valid.ndim == 1
                  else valid[:, None, :])                   # (T, 1, 1|C)
        is4kb = is4k[:, None, :]                            # (T, 1, C)
        idealb = mb(mt["ideal"])
        hugeb = mb(mt["huge"])
        bypb = mb(mt["bypass"])
        mem4 = d4(dp["mem_lat"])
        hier_lat = [dp["l1_lat"], dp["l2_lat"], dp["l3_lat"]][:len(hier)]
        # multi-stack remote-hop penalty per memory access: co-locating
        # mechanisms place frames in the local stack and dodge ~90% of
        # it.  stack_pen is 0.0 on single-stack machines, so this is an
        # exact +0.0 there (bit-stable vs the pre-zoo engine).
        pen = d3(dp["stack_pen"]) * jnp.where(mb(mt["colocate"]),
                                              0.1, 1.0)
        pen4 = pen[..., None]

        # per-access memory cost at each of the five line sites.
        # Bounded: flat latency + the mech's aggregate queue window.
        # Banked: closed-row latency, minus the precharge+activate the
        # scan-tracked row hit skips, plus the access's OWN bank's queue
        # window (gathered by bank index) — contiguous flat-leaf spans
        # keep their row open, scattered radix nodes mostly do not.
        if has_banked:
            rowhit = jnp.stack([bit(bank_bit + i) for i in range(5)], -1)
            bank5 = (lines // lines_per_row) % n_banks     # (T, M, C, 5)
            qfull = q[None, :, None, :] if q.ndim == 2 else q[None]
            q_acc = jnp.take_along_axis(
                jnp.broadcast_to(qfull, packed.shape + (n_banks,)),
                bank5, axis=-1)                            # (T, M, C, 5)
            mem_cost = (mem4 - rowhit * d4(dp["row_save"])
                        + q_acc + pen4)
        else:
            qb = q[None, :, None] if q.ndim == 1 else q[None]  # (1,M,1|C)
            mem_cost = mem4 + qb[..., None] + pen4

        h_l1tlb, h_l2tlb = bit(0), bit(1)
        en0 = validb & ~idealb & ~(mb(mt["segment"]) & ~is4kb)
        walk = en0 & ~h_l1tlb & ~h_l2tlb                    # (T, M, C)
        if has_ctlb:
            ctlb_probe = walk & mb(mt["cache_tlb"])
            walk = walk & ~bit(ctlb_bit)
        eff_n = jnp.where(hugeb & is4kb, MAX_PTE, mb(mt["n_pte"]))

        # hierarchy latency per line (pte0..3, data): chain the per-level
        # hit bits top-down; a line that misses everywhere pays memory + q
        lat = jnp.zeros(packed.shape + (5,), jnp.float32)
        reached = jnp.ones(packed.shape + (5,), bool)
        went_mem = jnp.ones(packed.shape + (5,), bool)
        for h_i in range(len(hier)):
            h = jnp.stack([bit(6 + 5 * h_i + i) for i in range(5)], -1)
            lat = lat + jnp.where(reached, d4(hier_lat[h_i]), 0.0)
            went_mem = went_mem & ~h
            reached = reached & ~h
        lat = lat + jnp.where(reached, mem_cost, 0.0)

        # per-PTE-level walk latency: PWC hit beats everything; NDPage
        # bypass goes straight to memory; cached mechanisms pay the chain
        pwc_hit = jnp.stack([bit(2 + lvl) for lvl in range(MAX_PTE)], -1)
        pte_en = (walk[..., None]
                  & (jnp.arange(MAX_PTE) < eff_n[..., None]))
        need_mem = pte_en & ~pwc_hit
        pte_lat = jnp.where(bypb[..., None],
                            mem_cost[..., :MAX_PTE],
                            lat[..., :MAX_PTE])
        pte_lat = jnp.where(pwc_hit, d4(dp["pwc_lat"]), pte_lat)
        pte_lat = jnp.where(pte_en, pte_lat, 0.0)

        # parallel (ECH) walks: all probes issue simultaneously and the
        # walk completes when the HITTING probe returns — one access
        # latency plus own-bank conflict + issue overhead.  The extra
        # probes only add traffic (counted in pte_mem -> queue pressure).
        # Multi-core: amortized cuckoo upsizing/rehash contention.
        walk_cyc = jnp.where(mb(mt["parallel"]),
                             pte_lat.max(-1) + 2.0 + d3(dp["ech_rehash"]),
                             pte_lat.sum(-1))

        trans = jnp.where(walk, walk_cyc, 0.0)
        if has_ctlb:
            # the cache-as-TLB probe is serial after the L2-TLB miss:
            # paid on hit AND miss; a hit replaces the walk entirely
            trans = trans + jnp.where(ctlb_probe, d3(dp["ctlb_lat"]),
                                      0.0)
        trans = jnp.where(en0 & ~h_l1tlb, d3(dp["l2tlb_lat"]) + trans, 0.0)
        trans = trans + jnp.where(hugeb & validb, d3(dp["promo"]), 0.0)

        pte_l1_hit = jnp.stack([bit(6 + i) for i in range(MAX_PTE)], -1)
        pte_mem = jnp.where(need_mem,
                            jnp.where(bypb[..., None], True,
                                      went_mem[..., :MAX_PTE]), False)
        data_mem = validb & went_mem[..., MAX_PTE]
        dlat = jnp.where(validb, lat[..., MAX_PTE], 0.0)

        step_cyc = jnp.where(
            validb,
            work[:, None, :] + 1.0 + trans + (dlat - d3(dp["l1_lat"])),
            0.0)

        # NB: XLA-CPU's axis-0 reduce keeps one association for every
        # lane width except 1 (rank-collapse special case), so these f32
        # sums are bit-stable between batch and single dispatch as long
        # as the lane minor-dim stays >= 2 — which simulate/simulate_batch
        # guarantee by padding 1-lane runs (integer-valued counters are
        # order-exact regardless).  tests/test_batch.py pins this.
        f32 = lambda a: a.astype(jnp.float32).sum(axis=0)   # noqa: E731
        cnt = {
            "trans": trans.sum(axis=0),
            "walks": f32(walk),
            "walk_cyc": jnp.where(walk, walk_cyc, 0.0).sum(axis=0),
            "l1tlb_miss": f32(en0 & ~h_l1tlb),
            "pte_acc": need_mem.astype(jnp.float32).sum(axis=(0, -1)),
            "pte_l1_hit": pte_l1_hit.astype(jnp.float32).sum(axis=(0, -1)),
            "pte_mem": pte_mem.astype(jnp.float32).sum(axis=(0, -1)),
            "data_l1_miss": f32(validb & ~bit(6 + MAX_PTE)),
            "data_mem": f32(data_mem),
        }
        if has_banked:
            # per-bank demand totals for the per-bank queue windows:
            # (M, C, banks) — the caller folds the core axis per sim
            acc5 = jnp.concatenate([pte_mem, data_mem[..., None]], -1)
            onehot = bank5[..., None] == jnp.arange(n_banks)
            mem_n = (acc5[..., None] & onehot).astype(
                jnp.float32).sum(axis=(0, 3))
        else:
            mem_n = (pte_mem.astype(jnp.float32).sum(axis=(0, -1))
                     + data_mem.astype(jnp.float32).sum(axis=0))
        return cnt, step_cyc.sum(axis=0), mem_n

    return make_step, epilogue


# ---------------------------------------------------------------------------
# chunked driver
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _chunk_runner(shape: MachineShape, walk_fns: Tuple, chunk: int,
                  batch: bool = False):
    """One jitted (scan + epilogue) over a chunk, specialized per
    (machine SHAPE, walk-fn tuple, chunk length) and cached for the life
    of the process.  Machine latencies (``dp``) and per-mechanism flag
    tables (``mt``) are operands, so every value-only machine or
    mechanism variant reuses the same compiled runner.  State buffers
    are donated: chunk i+1 reuses chunk i's memory.  The per-mechanism
    PTE walk lines are derived from the VPNs inside the jit so the host
    never materializes (T, C, M, MAX_PTE).

    ``batch=True`` builds the B-axis variant: xs arrive as (T, B, C)
    (valid: (T, B)), state carries a leading B, mt/dp carry a leading B
    (heterogeneous lanes), and the queue window is tracked per sim.
    One jitted callable serves every B (jit re-traces per shape) and
    every sharding of the B axis."""
    make_step, epilogue = _build_model(shape, batched=batch)
    table_names = tuple(n for n, _, _ in shape.tables)
    has_banked = shape.memory[0] == "banked"
    # banked memory: per-bank open-row ids join the scan carry, and the
    # epilogue needs the raw line ids to gather per-bank queue windows
    carry_names = table_names + (("bank_row",) if has_banked else ())

    def walk_lines(vpn, is4k, huge):
        """(..., C) vpns -> (..., C, M, MAX_PTE) PTE line ids.  ``huge``
        is runtime data ((M,) or (lanes, M)): huge-page mechanisms blend
        in the radix fallback lines for fragmented (4KB) regions."""
        radix = _pad_lines(PT.radix4_walk_lines(vpn))
        per_mech = []
        for i, fn in enumerate(walk_fns):
            if fn is None:
                lines = jnp.zeros_like(radix)
            elif fn is PT.radix4_walk_lines:
                lines = radix
            else:
                lines = _pad_lines(fn(vpn))
            h = huge[i] if huge.ndim == 1 else huge[None, :, i, None]
            lines = jnp.where(h & is4k[..., None], radix, lines)
            per_mech.append(lines)
        return jnp.stack(per_mech, axis=-2)

    def _queue(clock, mem_accs, service):
        # queue delay from demand measured so far (per mech, per sim).
        # Bounded-linear law: DRAM degrades gently up to saturation (an
        # M/M/1 knee over-penalizes small traffic deltas at high load).
        # Held constant within the chunk.  Banked: the same law applied
        # per BANK (mem_accs carries a trailing bank axis and service
        # is the per-bank occupancy) — traffic on one bank never delays
        # another.
        elapsed = jnp.maximum(clock.mean(axis=-1), 1.0)
        if has_banked:
            rate = mem_accs / elapsed[..., None]
            svc = (service if service.ndim == 0
                   else service[:, None, None])
            return MM.queue_delay(rate, svc)  # (M, bk) / batched (B, M, bk)
        rate = mem_accs / elapsed                 # aggregate accesses/cycle
        svc = service if service.ndim == 0 else service[:, None]
        rho = jnp.clip(rate * svc, 0.0, 0.96)
        return svc * rho * QUEUE_K                # (M,) / batched (B, M)

    def _lines5(pte, vpn, off):
        # the five access sites' line ids in epilogue orientation
        # (T, M, C, 5): pte0..3 from the walk, then the data line
        pm = jnp.swapaxes(pte, 1, 2)
        dl = (vpn * 64 + off)[:, None, :, None]
        return jnp.concatenate(
            [pm, jnp.broadcast_to(dl, pm.shape[:-1] + (1,))], -1)

    def run(state, xs, mt, dp):
        vpn, off, work, is4k, valid = xs
        pte = walk_lines(vpn, is4k, mt["huge"])
        q = _queue(state["clock"], state["mem_accs"], dp["service"])
        carry = ({k: state[k] for k in carry_names}, state["stamp"])
        (tabs, stamp), packed = jax.lax.scan(
            make_step(mt), carry, (vpn, off, pte, is4k, valid))
        # scan emits (T, C, M); the cheap summary arrays go back to the
        # public (T, M, C) orientation here
        cnt, cyc, mem_n = epilogue(
            jnp.swapaxes(packed, 1, 2), work, is4k, valid, q, mt, dp,
            lines=_lines5(pte, vpn, off) if has_banked else None)

        new_state = dict(tabs)
        new_state["stamp"] = stamp
        new_state["clock"] = state["clock"] + cyc
        new_state["mem_accs"] = state["mem_accs"] + mem_n.sum(axis=1)
        new_state["counters"] = {
            k: state["counters"][k] + cnt[k] for k in state["counters"]}
        return new_state

    def run_batch(state, xs, mt, dp):
        """B sims as one dispatch.  State arrives (B, C, M, ...) and is
        reshaped — free, the leading axes are contiguous — onto the
        fused (B*C, M, ...) lane layout the proven two-level engine
        runs; valid bits, queue windows, mechanism tables, and data
        params are expanded per lane.  Public counters stay per-sim
        (B, M, C)."""
        vpn, off, work, is4k, valid = xs          # (T, B, C); valid (T, B)
        t, b, c = vpn.shape
        m = state["stamp"].shape[-1]
        fuse = lambda a: a.reshape((t, b * c) + a.shape[3:])   # noqa: E731
        vpn, off, work, is4k = (fuse(a) for a in (vpn, off, work, is4k))
        valid = jnp.repeat(valid, c, axis=1)      # (T, B*C)
        mt_l = {k: jnp.repeat(v, c, axis=0) for k, v in mt.items()}
        dp_l = {k: jnp.repeat(v, c, axis=0) for k, v in dp.items()}
        pte = walk_lines(vpn, is4k, mt_l["huge"])
        q = _queue(state["clock"], state["mem_accs"],
                   dp["service"])                 # (B, M) / (B, M, bk)
        if has_banked:                            # -> (M, B*C, bk)
            q_lane = jnp.repeat(jnp.moveaxis(q, 0, 1), c, axis=1)
        else:
            q_lane = jnp.repeat(q.T, c, axis=1)   # (M, B*C)

        carry = (jax.tree.map(lambda a: a.reshape((b * c,) + a.shape[2:]),
                              {k: state[k] for k in carry_names}),
                 state["stamp"].reshape(b * c, m))
        (tabs, stamp), packed = jax.lax.scan(
            make_step(mt_l), carry, (vpn, off, pte, is4k, valid))
        cnt, cyc, mem_n = epilogue(
            jnp.swapaxes(packed, 1, 2), work, is4k, valid, q_lane,
            mt_l, dp_l,
            lines=_lines5(pte, vpn, off) if has_banked else None)

        def unfuse_mc(a):                 # (M, B*C, ...) -> (B, M, C, ...)
            return jnp.moveaxis(
                a.reshape((a.shape[0], b, c) + a.shape[2:]), 1, 0)

        new_state = jax.tree.map(
            lambda a: a.reshape((b, c) + a.shape[1:]), tabs)
        new_state["stamp"] = stamp.reshape(b, c, m)
        new_state["clock"] = state["clock"] + unfuse_mc(cyc)
        new_state["mem_accs"] = (state["mem_accs"]
                                 + unfuse_mc(mem_n).sum(axis=2))
        new_state["counters"] = {
            k: state["counters"][k] + unfuse_mc(cnt[k])
            for k in state["counters"]}
        return new_state

    return jax.jit(run_batch if batch else run, donate_argnums=(0,))


# a spec re-registered with overwrite=True must not keep serving runners
# compiled from the old MechTables/walk_fn
_mechanisms.on_register(_chunk_runner.cache_clear)


def _resolve_trace(trace, num_cores: int, length: int | None):
    """Accept a ``"trace:<path>"`` workload spec anywhere a trace dict
    is expected: resolved through :func:`repro.workloads.generate_trace`
    (which dispatches to the real-trace ingest layer), so every engine
    entry point replays real traces with zero engine changes."""
    if isinstance(trace, str):
        from repro.workloads import generate_trace, parse_workload_spec
        parse_workload_spec(trace)       # fail loudly at the boundary
        return generate_trace(trace, num_cores, length=length)
    return trace


def simulate(mach: MachineConfig, trace: Dict[str, np.ndarray] | str,
             length: int | None = None, *,
             mechs: Tuple[str, ...] | None = None,
             chunk: int = DEFAULT_CHUNK) -> SimResult:
    """Run the registered mechanisms over a multi-core trace on ``mach``.

    ``mechs`` selects/orders mechanisms from the spec registry (default:
    the paper's five).  The trace is zero-padded to a multiple of
    ``chunk`` (padding is masked out of every counter) and streamed
    through the cached chunk runner.  ``trace`` may be a
    ``"trace:<path>"`` spec for an ingested real trace.
    """
    names = DEFAULT_MECHS if mechs is None else tuple(mechs)
    trace = _resolve_trace(trace, mach.num_cores, length)

    if mach.num_cores == 1:
        # run 1-core sims on the batch engine (padded to 2 lanes there):
        # a single lane would hit XLA's width-1 reduce special case,
        # whose float accumulation order differs from every width >= 2 —
        # breaking batch-vs-single bit-exactness
        return simulate_batch(mach, [trace], length, mechs=names,
                              chunk=chunk, devices=1)[0]
    return _simulate_single(mach, trace, length, names, chunk)


def _simulate_single(mach: MachineConfig, trace: Dict[str, np.ndarray],
                     length: int | None, names: Tuple[str, ...],
                     chunk: int) -> SimResult:
    """The non-batched engine — every core count runs here via
    :func:`simulate` except C=1 (rerouted, see above).  The batch tests
    also drive this directly as an independent oracle (to float
    tolerance at C=1, where the rerouting makes exactness impossible).
    """
    m = len(specs_for(names))
    vpn = trace["vpn"][:, :length] if length else trace["vpn"]
    off = trace["off"][:, : vpn.shape[1]]
    work = trace["work"][:, : vpn.shape[1]]
    c, t = vpn.shape
    assert c == mach.num_cores, (c, mach.num_cores)

    # huge-page fragmentation: which 2MB regions fell back to 4KB mappings
    frac = FRAC_4K.get(mach.num_cores, min(0.93, 0.05 + 0.11 *
                                           mach.num_cores))
    region = vpn >> HUGE_SHIFT
    is4k_np = (_hash_np(region) % 1000) < int(frac * 1000)

    pad = (-t) % chunk
    pad_np = lambda a: np.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))  # noqa: E731
    valid = np.arange(t + pad) < t
    xs = (pad_np(np.ascontiguousarray(vpn.T, np.int32)),
          pad_np(np.ascontiguousarray(off.T, np.int32)),
          pad_np(np.ascontiguousarray(work.T, np.float32)),
          pad_np(np.ascontiguousarray(is4k_np.T)),
          valid)
    xs = tuple(jnp.asarray(a) for a in xs)

    runner = _chunk_runner(machine_shape(mach), _walk_fns(names), chunk)
    mt = {k: jnp.asarray(v) for k, v in _mech_arrays(names).items()}
    dp = {k: jnp.asarray(v) for k, v in _data_params(mach).items()}
    state = init_state(mach, m)
    for i in range(0, t + pad, chunk):
        state = runner(state, jax.tree.map(lambda a: a[i:i + chunk], xs),
                       mt, dp)
    state = jax.block_until_ready(state)

    cnt = {k: np.asarray(v) for k, v in state["counters"].items()}
    return SimResult(
        mechs=names,
        cycles=np.asarray(state["clock"]),
        instructions=np.asarray((work + 1).sum(axis=1), np.float64),
        trans_cycles=cnt["trans"],
        walk_cycles=cnt["walk_cyc"],
        walks=cnt["walks"],
        l1tlb_misses=cnt["l1tlb_miss"],
        accesses=t,
        pte_accesses=cnt["pte_acc"],
        pte_l1_hits=cnt["pte_l1_hit"],
        pte_mem=cnt["pte_mem"],
        data_l1_misses=cnt["data_l1_miss"],
        data_mem=cnt["data_mem"],
    )


def simulate_batch(mach: MachineConfig,
                   traces: Sequence[Dict[str, np.ndarray] | str],
                   length: int | None = None, *,
                   mechs: Tuple[str, ...] | None = None,
                   chunk: int = DEFAULT_CHUNK,
                   devices: int | None = None,
                   timings: Dict | None = None) -> List[SimResult]:
    """Run B independent simulations sharing ``mach`` as ONE batched
    chunked-scan dispatch.

    ``traces`` is a sequence of trace dicts (each ``(num_cores, T_i)``)
    — or ``"trace:<path>"`` specs, resolved through the real-trace
    ingest layer; lanes with shorter traces are masked with per-sim
    valid bits, so mixed-length buckets are fine.  Results are bit-exact vs calling
    :func:`simulate` per trace — state is laid out ``(B, C, M, sets,
    ways)`` and fused to a wider lane axis at dispatch; lanes never
    interact.  Thin wrapper over :func:`simulate_batch_varied` with
    every lane on the same machine and mechanism tuple.

    ``devices`` shards the B axis over that many XLA devices (default:
    all of them when ``SIM_DEVICES`` forced multiple host devices,
    else 1); B is padded to a device multiple with all-invalid lanes.
    ``timings``, if given, is filled with wall clock for the benchmark
    drivers: "total_s", "compile_s_est" (first-chunk excess over the
    steady per-chunk rate), "run_s" (= total - compile estimate), and
    "chunks".
    """
    names = DEFAULT_MECHS if mechs is None else tuple(mechs)
    return simulate_batch_varied(
        [SimJob(mach, tr, names) for tr in traces], length,
        chunk=chunk, devices=devices, timings=timings)


@dataclasses.dataclass
class SimJob:
    """One lane of a varied batch: a machine, its trace, and the
    mechanism tuple to evaluate.  All jobs of one
    :func:`simulate_batch_varied` call must share the machine SHAPE
    (:func:`machine_shape`) and the mechanisms' walk-fn tuple —
    everything value-like (latencies, service time, bypass/PWC/huge
    flags, walk depth) may differ per lane."""

    mach: MachineConfig
    trace: Dict[str, np.ndarray] | str
    mechs: Tuple[str, ...] = DEFAULT_MECHS


def simulate_batch_varied(jobs: Sequence[SimJob],
                          length: int | None = None, *,
                          chunk: int = DEFAULT_CHUNK,
                          devices: int | None = None,
                          timings: Dict | None = None) -> List[SimResult]:
    """B heterogeneous (machine, trace, mechanisms) jobs as ONE batched
    chunked-scan dispatch — the sweep engine's bucket primitive.

    The jobs must form one *shape bucket*: equal :func:`machine_shape`
    and equal mechanism walk-fn tuples (a ``ValueError`` names the
    offender otherwise).  Everything value-like varies per lane via the
    mt/dp operand stacks, so e.g. a memory-latency grid or an L1-bypass
    ablation is a single dispatch with zero extra compiles.
    """
    b = len(jobs)
    if b == 0:
        return []
    jobs = [j if not isinstance(j.trace, str)
            else dataclasses.replace(
                j, trace=_resolve_trace(j.trace, j.mach.num_cores, length))
            for j in jobs]
    shape = machine_shape(jobs[0].mach)
    wf = _walk_fns(jobs[0].mechs)
    m = len(specs_for(jobs[0].mechs))
    c = shape.num_cores
    for j in jobs:
        if machine_shape(j.mach) != shape:
            raise ValueError(
                f"job {j.mach.name!r} breaks the shape bucket: "
                f"{machine_shape(j.mach)} != {shape} — split the batch "
                "by machine_shape() first")
        if _walk_fns(j.mechs) != wf:
            raise ValueError(
                f"job mechs {j.mechs} have different walk functions "
                "than the bucket's — bucket by walk-fn tuple first")

    vpns, offs, works, lens = [], [], [], []
    for j in jobs:
        vpn = j.trace["vpn"][:, :length] if length else j.trace["vpn"]
        assert vpn.shape[0] == c, (vpn.shape[0], c)
        vpns.append(vpn)
        offs.append(j.trace["off"][:, : vpn.shape[1]])
        works.append(j.trace["work"][:, : vpn.shape[1]])
        lens.append(vpn.shape[1])
    t_pad = max(lens) + (-max(lens)) % chunk

    ndev = devices
    if ndev is None:
        ndev = len(jax.devices()) if os.environ.get("SIM_DEVICES") else 1
    ndev = max(1, min(ndev, len(jax.devices()), b))
    bp = b + (-b) % ndev                 # pad B to a device multiple
    if bp * c < 2:
        bp = 2      # keep the fused lane axis >= 2 wide: XLA's width-1
        #             reduce reassociates (see epilogue comment)

    def pack(arrs, dtype):
        out = np.zeros((t_pad, bp, c), dtype)
        for i, a in enumerate(arrs):
            out[: lens[i], i] = np.ascontiguousarray(a.T)
        return out

    # huge-page fragmentation: which 2MB regions fell back to 4KB
    is4ks = []
    for j, v in zip(jobs, vpns):
        frac = FRAC_4K.get(j.mach.num_cores, min(0.93, 0.05 + 0.11 *
                                                 j.mach.num_cores))
        is4ks.append((_hash_np(v >> HUGE_SHIFT) % 1000) < int(frac * 1000))
    valid = np.zeros((t_pad, bp), bool)
    for i, n in enumerate(lens):
        valid[:n, i] = True
    xs = (pack(vpns, np.int32), pack(offs, np.int32),
          pack(works, np.float32), pack(is4ks, bool), valid)
    xs = tuple(jnp.asarray(a) for a in xs)

    # per-lane value stacks; pad lanes reuse job 0 (their valid bits are
    # all False, so their counters are discarded anyway)
    pad_jobs = list(jobs) + [jobs[0]] * (bp - b)
    mts = [_mech_arrays(j.mechs) for j in pad_jobs]
    dps = [_data_params(j.mach) for j in pad_jobs]
    mt = {k: jnp.asarray(np.stack([t[k] for t in mts])) for k in mts[0]}
    dp = {k: jnp.asarray(np.stack([d[k] for d in dps])) for k in dps[0]}

    state = init_state(jobs[0].mach, m, batch=bp)
    if ndev > 1:
        mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("b",))
        st_sh = NamedSharding(mesh, P("b"))    # state: B leading everywhere
        xs_sh = NamedSharding(mesh, P(None, "b"))   # xs: (T, B, ...)
        state = jax.tree.map(lambda a: jax.device_put(a, st_sh), state)
        xs = tuple(jax.device_put(a, xs_sh) for a in xs)
        mt = {k: jax.device_put(v, st_sh) for k, v in mt.items()}
        dp = {k: jax.device_put(v, st_sh) for k, v in dp.items()}

    runner = _chunk_runner(shape, wf, chunk, batch=True)
    n_chunks = t_pad // chunk
    t0 = time.perf_counter()
    t_first = 0.0
    for k, i in enumerate(range(0, t_pad, chunk)):
        state = runner(state, jax.tree.map(lambda a: a[i:i + chunk], xs),
                       mt, dp)
        if timings is not None and k == 0:
            # one extra sync: the first chunk carries trace+compile cost,
            # later chunks stay pipelined (async dispatch)
            jax.block_until_ready(state)
            t_first = time.perf_counter() - t0
    state = jax.block_until_ready(state)
    if timings is not None:
        total = time.perf_counter() - t0
        steady = ((total - t_first) / (n_chunks - 1)
                  if n_chunks > 1 else 0.0)
        timings["chunks"] = n_chunks
        timings["total_s"] = total
        timings["compile_s_est"] = max(0.0, t_first - steady)
        timings["run_s"] = total - timings["compile_s_est"]

    cnt = {k: np.asarray(v) for k, v in state["counters"].items()}
    clock = np.asarray(state["clock"])
    return [SimResult(
        mechs=jobs[i].mechs,
        cycles=clock[i],
        instructions=np.asarray((works[i] + 1).sum(axis=1), np.float64),
        trans_cycles=cnt["trans"][i],
        walk_cycles=cnt["walk_cyc"][i],
        walks=cnt["walks"][i],
        l1tlb_misses=cnt["l1tlb_miss"][i],
        accesses=lens[i],
        pte_accesses=cnt["pte_acc"][i],
        pte_l1_hits=cnt["pte_l1_hit"][i],
        pte_mem=cnt["pte_mem"][i],
        data_l1_misses=cnt["data_l1_miss"][i],
        data_mem=cnt["data_mem"][i],
    ) for i in range(b)]


def _pad_lines(a: jnp.ndarray) -> jnp.ndarray:
    """Pad (T, C, d) walk lines to (T, C, MAX_PTE)."""
    pad = [(0, 0)] * (a.ndim - 1) + [(0, MAX_PTE - a.shape[-1])]
    return jnp.pad(a, pad)


def _hash_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) ^ np.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)
