"""Deprecated import path — the implementation lives in
``repro.sim._sweep``; import :func:`sweep` / :func:`run_bucketed` /
:func:`apply_param` from :mod:`repro.sim` instead."""
import warnings

from repro.sim._sweep import (_RESULT_FIELDS,  # noqa: F401
                              SweepPoint, SweepResult, apply_param,
                              checkpoint_key, named_sweep, run_bucketed,
                              sweep)

warnings.warn(
    "repro.sim.sweep is deprecated; import sweep / run_bucketed / "
    "apply_param from repro.sim instead",
    DeprecationWarning, stacklevel=2)
