"""Pipeline parallelism: a GPipe-style stage runner on a mesh axis.

The main training path uses DP/FSDP/TP/EP (scan-over-layers keeps
activations resident, which on TPU pods beats PP for the assigned dense
sizes); this module provides the PP substrate for depth-dominated regimes
(e.g. granite-34b's 88 layers on small-HBM parts): stages are laid out on
a mesh axis and microbatches stream through with `ppermute` handoffs under
shard_map.

Schedule: classic GPipe fill-drain.  For S stages and M microbatches the
loop runs S+M-1 ticks; stage s computes microbatch (t - s) when
0 <= t - s < M.  Bubble fraction = (S-1)/(S+M-1).

The stage function must be shape-preserving (d_model in == d_model out),
which matches this framework's block stacks.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_run(mesh: Mesh, axis: str, stage_fn: Callable,
                 stage_params: Any, x_micro: jnp.ndarray) -> jnp.ndarray:
    """Run microbatches through pipeline stages laid out on ``axis``.

    stage_fn(params_slice, x) -> x            (one stage's computation)
    stage_params: pytree with leading dim == num_stages (sharded on axis)
    x_micro: (M, mb, S, D) microbatches (replicated over ``axis``)

    Returns (M, mb, S, D) outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    m = x_micro.shape[0]
    ticks = n_stages + m - 1

    def body(params_l, xs_l):
        # params_l: this stage's params (leading dim 1); xs_l: all micros
        params_me = jax.tree.map(lambda a: a[0], params_l)
        sid = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs_l[0])          # current carried activation
        outs = jnp.zeros_like(xs_l)

        def tick(t, state):
            buf, outs = state
            # stage 0 ingests microbatch t; others take the permuted buf
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jnp.where(sid == 0, 1, 0)
            x_in = jnp.where(inject, xs_l[mb_idx], buf)
            active = (t - sid >= 0) & (t - sid < m)
            y = stage_fn(params_me, x_in)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            write = (sid == n_stages - 1) & (t - (n_stages - 1) >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: o.at[out_idx].set(y),
                lambda o: o, outs)
            # hand activations downstream (ring; stage S-1 -> 0 is ignored)
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage wrote into outs (others kept zeros):
        # a psum over the axis broadcasts the finished microbatches
        return jax.lax.psum(outs, axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)
