from repro.parallel.sharding import (  # noqa: F401
    batch_axes,
    constrain,
    param_sharding,
    param_spec,
    state_sharding,
    valid_spec,
)
