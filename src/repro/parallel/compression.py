"""Gradient compression: int8 error-feedback all-reduce.

At 1000+ nodes the DP all-reduce of bf16/f32 gradients dominates step time
for small-per-chip models.  This module quantizes per-leaf gradients to
int8 with a per-leaf scale before the data-parallel reduction and carries
the quantization error forward (error feedback keeps SGD/Adam convergence;
Karimireddy et al. 2019).

``make_ef_compressor`` returns a stateful-through-carry transform usable
inside train_step; under shard_map the psum really moves int8 on the wire
(4x less DP traffic).  Without a mesh it degrades to a pure
quantize-dequantize round trip (tests validate error-feedback behaviour).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_quantize(grads, error):
    """Error-feedback quantization: returns (dequantized grads, new error)."""
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq, g32 - dq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def zeros_error_like(grads):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), grads)


def make_dp_int8_allreduce(mesh: Mesh, axis: str = "data"
                           ) -> Callable[[Any], Any]:
    """shard_map-based all-reduce that moves int8 over the wire.

    Use for gradients that are fully replicated over ``axis`` (pure-DP
    leaves).  Each shard quantizes its local contribution, psums the int8
    payload (widened to int32 for the reduction), and rescales by the max
    of the per-shard scales.
    """
    from jax.experimental.shard_map import shard_map

    def allreduce(g: jnp.ndarray) -> jnp.ndarray:
        def body(x):
            q, s = quantize_int8(x)
            s_max = jax.lax.pmax(s, axis)
            # requantize against the global scale so payloads are additive
            q = jnp.clip(jnp.round(x / s_max), -127, 127).astype(jnp.int8)
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
            return total.astype(jnp.float32) * s_max / n.astype(jnp.float32)

        spec = P(*([None] * g.ndim))
        return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec)(g)

    return lambda grads: jax.tree.map(allreduce, grads)
