"""Logical-axis sharding rules: DP / FSDP / TP / EP over the mesh.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Batch shards over (pod, data); tensor-parallel dims over
``model``; MoE experts over ``model`` (expert parallelism); with
``cfg.fsdp`` parameter/optimizer d_model dims additionally shard over
``data`` (ZeRO-3 analogue).

Every rule passes through :func:`valid_spec`, which drops a mesh axis from
any tensor dimension it does not divide — small archs (4 heads, kv=1)
degrade gracefully to replication on that dim instead of erroring, and the
roofline table shows the cost.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def valid_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh,
               allow_uneven: bool = False) -> P:
    """Drop mesh axes that don't divide their tensor dim (graceful TP).

    ``allow_uneven``: keep a single axis on a non-divisible dim when the
    dim is at least the axis size (GSPMD pads; <=2x worst-case waste beats
    full replication).  Used for activation constraints (e.g. 40 heads over
    16-way model), never for parameters.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    used: set = set()
    for dim, axes in zip(shape, entries):
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        keep: list = []
        rem = dim
        for a in tup:
            if a not in mesh.axis_names or a in used:
                continue
            size = mesh.shape[a]
            if rem % size == 0:
                keep.append(a)
                used.add(a)
                rem //= size
            elif allow_uneven and not keep and rem >= size:
                keep.append(a)
                used.add(a)
                rem = -(-rem // size)
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules, dispatched on the param path
# ---------------------------------------------------------------------------
def _rule_for(path: Tuple[str, ...], ndim: int, fsdp: bool) -> P:
    """PartitionSpec for the TRAILING logical dims of a parameter."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    dp = "data" if fsdp else None

    if name == "embed" or name == "lm_head":
        return P("model", dp) if name == "embed" else P(dp, "model")
    # attention
    if name in ("wq", "wk", "wv"):
        return P(dp, "model")
    if name == "wo":
        return P("model", dp)
    # MLA
    if name in ("w_dq", "w_dkv"):
        return P(dp, None)
    if name in ("w_uq", "w_uk", "w_uv"):
        return P(None, "model")
    # MoE experts: EP over model on the expert dim + fsdp on d_model/d_ff
    if parent == "ffn" and name in ("w_up", "w_gate", "w_down") and ndim >= 3:
        return P("model", dp, None)
    if name == "router":
        return P(dp, None)
    # dense FFN (incl. shared experts, rwkv channel-mix w_k/w_v)
    if name in ("w_up", "w_gate", "w_k"):
        return P(dp, "model")
    if name in ("w_down", "w_v"):
        return P("model", dp)
    # mamba
    if name == "in_proj":
        return P(dp, "model")
    if name in ("conv_w", "conv_b", "x_proj", "A_log", "D"):
        return P("model")
    if name == "dt_proj":
        return P(None, "model")
    if name == "out_proj":
        return P("model", dp)
    # rwkv time-mix
    if name in ("w_r", "w_g"):
        return P(dp, "model")
    if name == "w_o":
        return P("model", dp)
    if name in ("w_A", "w_B"):
        return P(None, None)
    # norms, biases, scalars, mixes: replicate
    return P()


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool, scanned: bool) -> P:
    rule = _rule_for(path, len(shape) - (1 if scanned else 0), fsdp)
    entries = list(rule)
    if scanned:  # leading period axis from scan-over-layers: never sharded
        entries = [None] + entries
    return valid_spec(P(*entries), shape, mesh)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return tuple(names)


def param_sharding(params, mesh: Mesh, cfg) -> Any:
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs).

    Scanned stacks live under a path containing "scan"; their leading
    period axis is unsharded.
    """
    def spec_of(path, leaf):
        names = _path_names(path)
        scanned = "scan" in names
        return NamedSharding(
            mesh, param_spec(names, leaf.shape, mesh, cfg.fsdp, scanned))

    return jax.tree_util.tree_map_with_path(spec_of, params)


# ---------------------------------------------------------------------------
# decode-state rules
# ---------------------------------------------------------------------------
QHEAD_POOL_BUDGET = 12 * 2 ** 30


def qhead_strategy(mesh: Mesh, *, h: int, kh: int, hd: int,
                   n_attn_layers: int, n_pages: int, page: int) -> bool:
    """Single source of truth for the paged-KV decode layout (H5).

    True  -> query heads shard over "model", pool replicated over "model"
             (zero score-psum; softmax fully local) — MQA/small-K archs
             whose total pool fits the per-device budget.
    False -> head_dim shards over "model"; f32 score partials psum.
    Must agree between state_sharding (storage) and the shard_map attention
    (compute) or GSPMD inserts pool-sized reshards.
    """
    n_model = mesh.shape["model"]
    dp = _axis_size(mesh, batch_axes(mesh))
    bytes_repl = (n_pages * page * kh * hd * 2 * 2 * n_attn_layers
                  // max(dp, 1))
    return (h % n_model == 0 and kh < n_model
            and bytes_repl <= QHEAD_POOL_BUDGET)


def _state_rule(name: str, shape, mesh: Mesh, batch: Tuple[str, ...],
                scanned: bool, cfg=None) -> P:
    """KV caches / recurrent state. Trailing-dim rules; batch axes shard
    sequences across (pod, data)."""
    nd = len(shape) - (1 if scanned else 0)
    if name in ("k", "v"):            # dense cache (B, S, K, H)
        rule = [batch, "model", None, None]
        # prefer head sharding when divisible; else sequence sharding
        kv_heads = shape[-2]
        if kv_heads % mesh.shape["model"] == 0:
            rule = [batch, None, "model", None]
    elif name in ("kp", "vp"):        # paged pools (N, page, K, H)
        kv_heads = shape[-2]
        if kv_heads % mesh.shape["model"] == 0:
            rule = [batch, None, "model", None]
        elif cfg is not None and qhead_strategy(
                mesh, h=cfg.num_heads, kh=kv_heads, hd=shape[-1],
                n_attn_layers=_n_attn_layers(cfg), n_pages=shape[-4],
                page=shape[-3]):
            rule = [batch, None, None, None]      # replicate over model (H5)
        else:
            rule = [batch, None, None, "model"]   # shard head_dim
    elif name == "ckv":               # MLA latent (B, S, lora)
        rule = [batch, None, "model"]
    elif name == "kr":
        rule = [batch, None, None]
    elif name == "conv":              # mamba (B, d_in, K)
        rule = [batch, "model", None]
    elif name == "ssm":               # mamba (B, d_in, N)
        rule = [batch, "model", None]
    elif name == "wkv":               # rwkv (B, H, hs, hs)
        rule = [batch, "model", None, None]
    elif name in ("shift", "ffn_shift"):
        rule = [batch, None]
    else:
        rule = [None] * nd
    if scanned:
        rule = [None] + rule
    return valid_spec(P(*rule), shape, mesh)


def _n_attn_layers(cfg) -> int:
    return sum(1 for mk, _ in cfg.layer_kinds()
               if mk in ("attn", "attn_local"))


def state_sharding(state, mesh: Mesh, cfg) -> Any:
    batch = batch_axes(mesh)

    def spec_of(path, leaf):
        names = _path_names(path)
        scanned = "scan" in names
        name = names[-1]
        if name in ("lengths",):
            return NamedSharding(mesh, valid_spec(P(batch), leaf.shape, mesh))
        if name in ("table", "directory", "leaves"):
            sp = P(batch) if name != "leaves" else P()
            return NamedSharding(mesh, valid_spec(sp, leaf.shape, mesh))
        if name == "enc_out":
            return NamedSharding(
                mesh, valid_spec(P(batch, None, None), leaf.shape, mesh))
        return NamedSharding(
            mesh, _state_rule(name, leaf.shape, mesh, batch, scanned, cfg))

    return jax.tree_util.tree_map_with_path(spec_of, state)


def constrain(x: jnp.ndarray, mesh: Mesh, *entries) -> jnp.ndarray:
    """with_sharding_constraint with divisibility-checked spec."""
    sp = valid_spec(P(*entries), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, sp))
