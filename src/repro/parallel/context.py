"""Activation-sharding context.

Models stay mesh-agnostic; the launcher (dry-run, train, serve) installs a
mesh here and model code calls :func:`constrain_act` at block boundaries.
Without an installed mesh every call is a no-op (CPU smoke tests).

Why this exists (perf iteration H1, see EXPERIMENTS.md §Perf): with FSDP
weights sharded over the data axis, GSPMD may satisfy a contraction by
REsharding activations off the batch axis instead of all-gathering the
(much smaller) weight shards — observed as 16x-replicated attention dots
in the phi3 baseline.  Pinning activations to (batch -> data) at layer
boundaries forces the weight-gather strategy everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

BATCH = "__batch__"   # placeholder resolved to ("pod","data") of the mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def constrain_act(x, *entries):
    """with_sharding_constraint honoring divisibility; no-op without mesh.

    Use the BATCH sentinel for the batch dimension; e.g.
    ``constrain_act(x, BATCH, None, "model")``.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    from repro.parallel.sharding import batch_axes, valid_spec
    resolved = tuple(batch_axes(mesh) if e == BATCH else e for e in entries)
    spec = valid_spec(P(*resolved), x.shape, mesh, allow_uneven=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
