"""Fault tolerance for the training driver.

Mechanisms (all exercised by tests on CPU at smoke scale):

  * periodic + emergency checkpointing (SIGTERM / exception -> save before
    exit) through train.checkpoint's atomic commit protocol;
  * restart-exactness: the data pipeline is counter-based, so
    (params, opt, step) fully determine the continuation — a restarted run
    is bit-identical to an uninterrupted one;
  * retry-with-backoff wrapper for transient step failures (preemption,
    collective timeout) with an escape to checkpoint-restore when a step
    keeps failing;
  * straggler mitigation hook: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on real fleets this
    signal feeds the scheduler to replace the slow host; here it feeds
    metrics).
  * elastic restart: checkpoints are mesh-agnostic (unsharded logical
    arrays), so a restore may use a different device count.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.train import checkpoint as ckpt


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    backoff_s: float = 0.05
    straggler_factor: float = 3.0


@dataclass
class FaultStats:
    retries: int = 0
    restores: int = 0
    emergency_saves: int = 0
    straggler_steps: int = 0
    step_ema_s: float = 0.0


class GuardedTrainer:
    """Wraps a train_step with checkpoint/restart + retry + straggler
    accounting.  ``state`` must be a pytree; ``extra_fn`` supplies the
    data cursor stored alongside."""

    def __init__(self, cfg: FaultConfig, train_step: Callable,
                 state: Any, start_step: int = 0):
        self.cfg = cfg
        self.train_step = train_step
        self.state = state
        self.step = start_step
        self.stats = FaultStats()
        self._stop = False
        self._prev_sigterm = None

    # -- lifecycle -----------------------------------------------------------
    def install_signal_handler(self):
        def handler(signum, frame):
            self._stop = True
            self.stats.emergency_saves += 1
            ckpt.save(self.cfg.ckpt_dir, self.step, self.state,
                      extra={"emergency": True}, keep=self.cfg.keep)
        self._prev_sigterm = signal.signal(signal.SIGTERM, handler)

    def maybe_restore(self) -> bool:
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return False
        self.state, extra = ckpt.restore(self.cfg.ckpt_dir, self.state)
        self.step = step
        self.stats.restores += 1
        return True

    # -- the guarded step ----------------------------------------------------
    def run_step(self, batch) -> Optional[Dict]:
        if self._stop:
            return None
        t0 = time.monotonic()
        last_err = None
        for attempt in range(self.cfg.max_retries):
            try:
                self.state, metrics = self.train_step(self.state, batch)
                break
            except Exception as e:  # transient failure path
                last_err = e
                self.stats.retries += 1
                time.sleep(self.cfg.backoff_s * (2 ** attempt))
        else:
            # persistent failure: restore last good checkpoint and re-raise
            self.maybe_restore()
            raise RuntimeError(
                f"step {self.step} failed {self.cfg.max_retries}x"
            ) from last_err

        dt = time.monotonic() - t0
        ema = self.stats.step_ema_s
        if ema > 0 and dt > self.cfg.straggler_factor * ema:
            self.stats.straggler_steps += 1
        self.stats.step_ema_s = 0.9 * ema + 0.1 * dt if ema else dt

        self.step += 1
        if self.step % self.cfg.ckpt_every == 0:
            ckpt.save(self.cfg.ckpt_dir, self.step, self.state,
                      extra={"data_step": self.step}, keep=self.cfg.keep)
        return metrics
