"""Fault-tolerant checkpointing: atomic, mesh-agnostic, keep-last-k.

Layout per step:
    <dir>/step_000042/
        manifest.json     step, leaf paths/shapes/dtypes, data cursor, rng
        arrays.npz        one entry per pytree leaf (gathered to host)
    <dir>/LATEST          text file naming the last COMMITTED step

Commit protocol: write into ``step_X.tmp`` then os.replace -> ``step_X``
and rewrite LATEST; a crash mid-write never corrupts a committed
checkpoint (restart resumes from the previous LATEST).  Checkpoints store
unsharded logical arrays, so a restart may use a different mesh shape /
process count (elastic restart) — arrays are resharded on load by jit.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", p)) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically save ``tree`` (params/opt/rng pytree) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                     # atomic commit
    _write_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, step: int) -> None:
    tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def restore(ckpt_dir: str, like, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))

    keys = [k for k, _ in _flatten(like)]
    leaves = []
    for (k, proto) in _flatten(like):
        arr = data[k]
        assert tuple(arr.shape) == tuple(proto.shape), (k, arr.shape,
                                                        proto.shape)
        leaves.append(jnp.asarray(arr, dtype=proto.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest["extra"]
