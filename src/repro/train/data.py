"""Deterministic, shard-aware synthetic data pipeline.

Every (step, rank) slice of the token stream is derived by counter-based
hashing — no state beyond the step counter, so:
  * restart-exactness: resuming from a checkpoint replays the identical
    stream (the checkpoint stores only ``step``);
  * shard-awareness: each data-parallel rank generates exactly its slice,
    no host broadcast;
  * elasticity: re-slicing to a different data-parallel degree yields the
    same global batch.

A file-backed loader (token-bin memmap) with the same cursor semantics is
provided for real corpora.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _mix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xC4CEB9FE1A85EC53)
    x ^= x >> np.uint64(33)
    return x


@dataclasses.dataclass
class SyntheticLM:
    """Counter-based synthetic token stream."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, rank: int = 0, world: int = 1
                 ) -> Dict[str, np.ndarray]:
        assert self.global_batch % world == 0
        local = self.global_batch // world
        rows = np.arange(local) + rank * local
        cols = np.arange(self.seq_len + 1)
        ctr = (np.uint64(self.seed) << np.uint64(40)
               ^ (np.uint64(step) << np.uint64(20))[None, None]
               ^ (rows[:, None].astype(np.uint64) << np.uint64(12))
               ^ cols[None, :].astype(np.uint64))
        toks = (_mix64(ctr) % np.uint64(self.vocab_size)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, :-1]}

    def iter(self, start_step: int = 0, rank: int = 0, world: int = 1
             ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, rank, world)
            step += 1


@dataclasses.dataclass
class TokenBinLoader:
    """Memmap-backed loader over a flat int32 token file with the same
    (step, rank) cursor determinism as SyntheticLM."""
    path: str
    seq_len: int
    global_batch: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._tokens_per_step = self.global_batch * (self.seq_len + 1)

    @property
    def num_steps(self) -> int:
        return len(self._data) // self._tokens_per_step

    def batch_at(self, step: int, rank: int = 0, world: int = 1
                 ) -> Dict[str, np.ndarray]:
        local = self.global_batch // world
        base = (step % max(self.num_steps, 1)) * self._tokens_per_step
        off = base + rank * local * (self.seq_len + 1)
        chunk = np.asarray(self._data[off: off + local * (self.seq_len + 1)])
        toks = chunk.reshape(local, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, :-1]}


def add_modality_stubs(batch: Dict[str, np.ndarray], cfg,
                       seed: int = 0) -> Dict[str, np.ndarray]:
    """Attach deterministic frontend-stub embeddings for audio/vlm archs."""
    b = batch["tokens"].shape[0]
    rng = np.random.default_rng(seed)
    if cfg.vision_tokens:
        batch = dict(batch)
        batch["vision_embeds"] = rng.standard_normal(
            (b, cfg.vision_tokens, cfg.d_model), dtype=np.float32) * 0.02
    if cfg.is_encdec:
        batch = dict(batch)
        batch["audio_frames"] = rng.standard_normal(
            (b, cfg.encoder_seq_len, cfg.d_model), dtype=np.float32) * 0.02
    return batch
