"""train_step: microbatched grad-accumulation over the model zoo.

Memory discipline for 1M-token global batches at 100k+ vocab: the loss is
computed per microbatch inside a lax.scan (logits never exist at full batch)
and each microbatch's softmax-xent runs in f32 with a z-loss regularizer.
Gradients accumulate in f32, the AdamW update applies once per step.

Optional int8 error-feedback gradient compression (repro.parallel.
compression) can wrap the accumulated grads before the optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Z_LOSS = 1e-4
AUX_WEIGHT = 1e-2


class TrainState(NamedTuple):
    params: Any
    opt: Dict[str, Any]
    rng: jnp.ndarray


def init_train_state(cfg, key) -> TrainState:
    from repro.models import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params), rng=key)


def loss_fn(params, cfg, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Causal LM loss with masking + z-loss + MoE aux."""
    logits, aux = forward_train(params, cfg, batch)   # (B, S, V) f32
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.vision_tokens:
        # prepended vision positions produce logits but have no labels
        logits = logits[:, cfg.vision_tokens:]
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    else:
        mask = mask[:, 1:].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    xent = (logz - gold) * mask
    zloss = Z_LOSS * jnp.square(logz) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (xent.sum() + zloss.sum()) / denom + AUX_WEIGHT * aux
    return loss, {"xent": xent.sum() / denom, "aux": aux}


def _microbatches(batch: Dict[str, jnp.ndarray], n: int, mesh=None):
    """Split the global batch into n microbatches along a NEW leading dim.

    The batch dim of the input is data-sharded; after the reshape GSPMD
    could legally shard the MICRO dim instead (catastrophic: every device
    would own whole microbatches and the scan would all-gather them), so
    when a mesh is given we pin dim1 to the batch axes explicitly.
    """
    from repro.parallel.sharding import batch_axes, constrain

    def split(a):
        b = a.shape[0]
        assert b % n == 0, (b, n)
        out = a.reshape(n, b // n, *a.shape[1:])
        if mesh is not None:
            out = constrain(out, mesh, None, batch_axes(mesh))
        return out

    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg, opt_cfg: AdamWConfig, num_microbatches: int = 1,
                    compress=None, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``compress``: optional fn(grads) -> grads applied after accumulation
    (e.g. parallel.compression.ef_int8_allreduce under shard_map).
    ``mesh``: enables explicit microbatch/grad sharding constraints.
    """

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if num_microbatches == 1:
            (loss, parts), grads = grad_fn(state.params, cfg, batch)
        else:
            micro = _microbatches(batch, num_microbatches, mesh)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, parts), g = grad_fn(state.params, cfg, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), parts

            g0 = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), state.params)
            if mesh is not None:
                from repro.parallel.sharding import param_sharding
                g0 = jax.tree.map(
                    jax.lax.with_sharding_constraint, g0,
                    param_sharding(g0, mesh, cfg))
            (grads, loss), parts = jax.lax.scan(
                acc_body, (g0, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda a: a / num_microbatches, grads)
            loss = loss / num_microbatches
            parts = jax.tree.map(lambda a: a.mean(), parts)

        if compress is not None:
            grads = compress(grads)
        params, opt, om = adamw_update(opt_cfg, state.params, grads,
                                       state.opt)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(params=params, opt=opt, rng=state.rng), metrics

    return train_step
