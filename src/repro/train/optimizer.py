"""AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup + cosine-decay schedule.  Pure JAX, no optax dependency.

Moments are stored in f32 and shard exactly like their parameters (the
param_sharding rules apply leaf-wise), giving ZeRO-style optimizer-state
sharding for fsdp archs for free.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
