from repro.train.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.train.train_loop import (  # noqa: F401
    TrainState,
    init_train_state,
    make_train_step,
    loss_fn,
)
