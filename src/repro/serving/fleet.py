"""Fleet-scale continuous batching: thousands of live sequences,
array-at-once.

:class:`repro.serving.BatchScheduler` / :class:`ServeEngine` run the
real model at toy batch sizes; this module is the same serving design
scaled to production shape.  :class:`FleetScheduler` keeps every piece
of per-request state in struct-of-arrays form so admission, deadline
sweeps, page growth, translation pricing and retirement are single
numpy operations over the whole batch — NO per-request Python loop runs
on the step path (per-request work happens only at per-lifetime events:
submit, admission placement, preemption, retirement).

The pieces, mirroring the small-batch stack one-for-one:

  mapping          a (max_batch, max_pages) int32 slot table over a
                   refcounted :class:`~repro.core.kv_page_manager.
                   PagePool` — the KV page manager's role, vectorized
  translation      a per-slot dirty bit replaces the TranslationCache:
                   a slot hits unless its mapping changed since the
                   last priced step (the LRU's capacity, 4x batch,
                   exceeds the running set, so the semantics coincide)
  pricing          :meth:`TranslationMeter.record_slots` — the
                   vectorized twin of ``record_step``: per-slot budget
                   matrix, flushed to dicts only at release
  prefix sharing   requests carrying the same ``prefix_id`` share the
                   fully-covered pages of their prompt head through
                   pool refcounts; radix-org line pricing then dedups
                   identical leaves batch-globally
                   (``cost_model._np_row_lines_shared``) — the radix
                   line-sharing win the flat org cannot have
  admission        priority-ordered feasibility by cumulative page
                   need AND (optionally) cumulative estimated
                   translation cycles against ``translation_budget`` —
                   translation cost as a first-class admission input

:class:`FleetEngine` drives the loop with a single jitted surrogate
decode (a deterministic hash of ``(token, position)`` — greedy-decode
shaped, resume-exact, and compiled exactly ONCE for the whole fleet:
``decode_trace_count()`` exposes the trace counter the benchmark
gates).  Teacher-forced replay after preemption rebuilds the stream
bit-exactly, so the evict-storm chaos invariant holds at fleet scale.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_table as BT
from repro.core.kv_page_manager import PagePool
from repro.serving._scheduler import Request
from repro.sim.cost_model import (ORG_FLAT, ORG_INV, ORG_RADIX,
                                  _usable_leaf_size)
from repro.util import resilience

#: request store states
QUEUED, RUNNING, DONE, FAILED = 0, 1, 2, 3

#: surrogate-decode vocabulary (any fixed power of two works)
VOCAB = 32768

#: times the surrogate decode body has been TRACED (not called) — the
#: benchmark asserts the whole fleet runs on one compiled graph
_DECODE_TRACES = [0]


def decode_trace_count() -> int:
    return _DECODE_TRACES[0]


@functools.lru_cache(maxsize=None)
def _decode_fn(vocab: int):
    """The jitted surrogate decode: next token = integer hash of
    (current token, position).  Deterministic per (token, pos), so a
    preempted request that teacher-forces its prompt + prior tokens
    reproduces the continuation bit-exactly — the same property greedy
    decode gives the real-model engine."""

    @jax.jit
    def step(tokens: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
        _DECODE_TRACES[0] += 1         # traced once per compilation
        x = tokens.astype(jnp.uint32) * jnp.uint32(2654435761)
        x = x + pos.astype(jnp.uint32) * jnp.uint32(40503)
        x = x + jnp.uint32(0x9E3779B9)
        x = x ^ (x >> 13)
        x = x * jnp.uint32(2246822519)
        x = x ^ (x >> 16)
        return (x % jnp.uint32(vocab)).astype(jnp.int32)

    return step


class FleetScheduler:
    """Struct-of-arrays continuous-batching scheduler (see module doc).

    Semantics mirror :class:`BatchScheduler`: priority admission with
    per-class head-of-line blocking, exponential-backoff preemption,
    shedding after ``max_retries``, queued-deadline drops, and
    translation pricing of every step under all mechanisms at once.
    """

    FAILED_HISTORY = 4096

    _R_FIELDS = ("r_prio", "r_deadline", "r_submit", "r_not_before",
                 "r_retries", "r_max_retries", "r_max_new", "r_prefix",
                 "r_prefix_len", "r_base", "r_eff", "r_status",
                 "r_admit_seq")

    def __init__(self, *, num_pages: int, max_batch: int, page_size: int,
                 max_len: int, leaf_size: int = 4,
                 flatten_threshold: float = 0.5,
                 table_mode: Optional[str] = None, meter=None,
                 prefix_sharing: bool = True,
                 translation_budget: Optional[float] = None,
                 budget_mech: str = "ndpage", budget_patience: int = 4,
                 failed_history: int = FAILED_HISTORY):
        self.pool = PagePool(num_pages)
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages = -(-max_len // page_size)
        self.leaf_size = _usable_leaf_size(self.max_pages, leaf_size)
        self.flatten_threshold = flatten_threshold
        self.table_mode = table_mode
        self.meter = meter
        self.prefix_sharing = prefix_sharing
        self.translation_budget = translation_budget
        self.budget_mech = budget_mech
        self.budget_patience = budget_patience
        if translation_budget is not None:
            if meter is None:
                raise ValueError("translation_budget needs a meter "
                                 "(admission estimates price against "
                                 "its cost model)")
            self._budget_cost = meter.model.cost(budget_mech)
        self._over_budget = 0
        self._est_sum = 0.0

        b = max_batch
        # -- slot state (the step-path arrays) ------------------------------
        self.slot_req = np.full(b, -1, np.int64)      # request-store index
        self.slot_pages = np.full((b, self.max_pages), -1, np.int32)
        self.slot_npages = np.zeros(b, np.int32)
        self.slot_len = np.zeros(b, np.int32)         # steps taken
        self.slot_eff = np.zeros(b, np.int32)         # stream length
        self.slot_base = np.zeros(b, np.int32)        # original prompt len
        self.slot_miss = np.zeros(b, bool)            # mapping changed
        self.slot_tokens = np.zeros((b, max_len), np.int32)
        self.slot_pfx = np.full(b, -1, np.int64)      # live prefix id
        self.slot_est = np.zeros(b, np.float64)       # admission estimate
        self._free_slots = list(range(b - 1, -1, -1))

        # -- request store (struct-of-arrays, capacity-doubled) -------------
        self._cap = 1024
        self._n = 0
        self.reqs: List[Request] = []
        self.r_prio = np.zeros(self._cap, np.int32)
        self.r_deadline = np.full(self._cap, -1, np.int32)
        self.r_submit = np.zeros(self._cap, np.int32)
        self.r_not_before = np.zeros(self._cap, np.int32)
        self.r_retries = np.zeros(self._cap, np.int32)
        self.r_max_retries = np.zeros(self._cap, np.int32)
        self.r_max_new = np.zeros(self._cap, np.int32)
        self.r_prefix = np.full(self._cap, -1, np.int64)
        self.r_prefix_len = np.zeros(self._cap, np.int32)
        self.r_base = np.zeros(self._cap, np.int32)
        self.r_eff = np.zeros(self._cap, np.int32)
        self.r_status = np.zeros(self._cap, np.int8)
        self.r_admit_seq = np.full(self._cap, -1, np.int64)

        # -- prefix registry (prefix_id -> live shared pages) ---------------
        self._pfx_pages: Dict[int, np.ndarray] = {}
        self._pfx_sharers: Dict[int, int] = {}

        self.clock = 0
        self._admit_seq = 0
        self.stats = {"admitted": 0, "completed": 0, "preempted": 0,
                      "shed": 0, "deadline_dropped": 0, "resumed": 0,
                      "steps": 0, "peak_running": 0,
                      "mode_flat_steps": 0, "mode_radix_steps": 0}
        self.failed: Deque[Request] = deque(maxlen=failed_history)

    # -- submission ----------------------------------------------------------
    def _ensure(self, n: int) -> None:
        if n <= self._cap:
            return
        cap = max(self._cap * 2, n)
        for name in self._R_FIELDS:
            arr = getattr(self, name)
            grown = np.full(cap, -1, arr.dtype) if name in (
                "r_deadline", "r_prefix", "r_admit_seq") \
                else np.zeros(cap, arr.dtype)
            grown[:self._cap] = arr
            setattr(self, name, grown)
        self._cap = cap

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError("fleet requests need max_new_tokens >= 1")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.req_id}: prompt + max_new_tokens = "
                f"{len(req.prompt) + req.max_new_tokens} exceeds "
                f"max_len {self.max_len}")
        if req.prefix_id is not None and req.prefix_id < 0:
            raise ValueError(f"prefix_id must be >= 0, got {req.prefix_id}")
        if req.submit_tick < 0:
            req.submit_tick = self.clock
        i = self._n
        self._ensure(i + 1)
        self.reqs.append(req)
        self.r_prio[i] = req.priority
        self.r_deadline[i] = (-1 if req.deadline_steps is None
                              else req.deadline_steps)
        self.r_submit[i] = req.submit_tick
        self.r_not_before[i] = req.not_before
        self.r_retries[i] = req.retries
        self.r_max_retries[i] = req.max_retries
        self.r_max_new[i] = req.max_new_tokens
        self.r_prefix[i] = -1 if req.prefix_id is None else req.prefix_id
        self.r_prefix_len[i] = req.prefix_len
        self.r_base[i] = len(req.prompt)
        self.r_eff[i] = len(req.effective_prompt())
        self.r_status[i] = QUEUED
        self.r_admit_seq[i] = -1
        self._n += 1

    def tick(self) -> None:
        self.clock += 1

    # -- introspection -------------------------------------------------------
    @property
    def num_running(self) -> int:
        return self.max_batch - len(self._free_slots)

    def has_queued(self) -> bool:
        return bool((self.r_status[:self._n] == QUEUED).any())

    def occupancy(self) -> float:
        """Used slots / mapped slots across live sequences (the flatten
        signal, same definition as ``KVPageManager.occupancy``)."""
        act = self.slot_req >= 0
        mapped = int(self.slot_npages[act].sum()) * self.page_size
        return int(self.slot_eff[act].sum()) / mapped if mapped else 0.0

    def preferred_mode(self) -> str:
        return (BT.FLAT if self.occupancy() >= self.flatten_threshold
                else BT.RADIX)

    # -- admission (array-at-once) ------------------------------------------
    def _deadline_sweep(self) -> None:
        n = self._n
        expired = np.flatnonzero(
            (self.r_status[:n] == QUEUED) & (self.r_deadline[:n] >= 0)
            & (self.clock - self.r_submit[:n] > self.r_deadline[:n]))
        for i in expired:                 # per-event, bounded by drops
            self._fail(int(i), "deadline")
        self.stats["deadline_dropped"] += expired.size

    def _fail(self, idx: int, reason: str) -> None:
        req = self.reqs[idx]
        req.failed = reason
        self.r_status[idx] = FAILED
        self.failed.append(req)
        if self.meter is not None:
            self.meter.retire_request(req.req_id)

    def _estimate(self, idxs: np.ndarray) -> np.ndarray:
        """Estimated steady-state translation cycles/step per candidate
        under the budget mechanism: a decode step misses when the
        mapping grew (one page boundary per ``page_size`` tokens), and
        a miss walks the candidate's FINAL table (prompt + full
        generation budget) — the conservative admission price."""
        c = self._budget_cost
        final = self.r_base[idxs] + self.r_max_new[idxs]
        pages = (-(-final // self.page_size)).astype(np.float64)
        if c.org == ORG_FLAT:
            lines = np.ceil(pages / BT.PTE_PER_LINE)
        elif c.org == ORG_RADIX:
            lines = 1.0 + np.ceil(pages / self.leaf_size)
        elif c.org == ORG_INV:
            lines = pages
        else:
            lines = np.ones_like(pages)
        p_miss = 1.0 / self.page_size
        return (p_miss * (c.walk + c.pte_line * np.maximum(lines - 1, 0))
                + (1.0 - p_miss) * c.tlb_hit)

    def admit(self) -> List[int]:
        """One admission sweep: drop expired deadlines, order eligible
        queued requests by (priority desc, submit order), and admit the
        longest feasible head — cumulative page need (prefix-discounted
        for live shared prefixes) within the pool AND, under a
        translation budget, cumulative estimated cycles within budget.
        Head-of-line blocking: the first infeasible candidate stops the
        sweep (no starvation of big requests).  Returns filled slots."""
        self._deadline_sweep()
        n = self._n
        eligible = np.flatnonzero(
            (self.r_status[:n] == QUEUED)
            & (self.r_not_before[:n] <= self.clock))
        if eligible.size == 0 or not self._free_slots:
            return []
        order = eligible[np.lexsort((eligible, -self.r_prio[eligible]))]
        eff = np.maximum(self.r_eff[order], 1)
        need = (-(-eff // self.page_size) + 1).astype(np.int64)
        if self.prefix_sharing and self._pfx_pages:
            # discount pages an already-live prefix will provide
            alive = np.sort(np.fromiter(self._pfx_pages, np.int64,
                                        len(self._pfx_pages)))
            sizes = np.asarray([len(self._pfx_pages[int(p)])
                                for p in alive], np.int64)
            pf = self.r_prefix[order]
            pos = np.minimum(np.searchsorted(alive, pf), alive.size - 1)
            live = (pf >= 0) & (alive[pos] == pf)
            cover = np.minimum(self.r_prefix_len[order] // self.page_size,
                               np.where(live, sizes[pos], 0))
            need = np.maximum(need - cover, 1)
        ok = np.cumsum(need) <= self.pool.free_pages
        ok &= np.arange(order.size) < len(self._free_slots)
        if self.translation_budget is not None:
            est = self._estimate(order)
            ok &= (np.cumsum(est) + self._est_sum) <= self.translation_budget
        k = order.size if bool(ok.all()) else int(np.argmin(ok))
        return [self._place(int(i)) for i in order[:k]]

    def _place(self, idx: int) -> int:
        """Put one admitted request into a slot (a per-lifetime event:
        the token-stream copy and prefix-registry bookkeeping are
        inherently per-request; the step path never loops)."""
        req = self.reqs[idx]
        slot = self._free_slots.pop()
        stream = np.asarray(req.effective_prompt(), np.int32)
        eff = max(len(stream), 1)
        need = -(-eff // self.page_size)
        row = self.slot_pages[slot]
        shared = 0
        pid = req.prefix_id if self.prefix_sharing else None
        register = False
        if pid is not None:
            full = req.prefix_len // self.page_size   # fully-covered only
            if pid in self._pfx_pages:
                pfx = self._pfx_pages[pid]
                shared = min(full, len(pfx), need)
                if shared:
                    self.pool.share_array(pfx[:shared])
                    row[:shared] = pfx[:shared]
                    self._pfx_sharers[pid] += 1
                    self.slot_pfx[slot] = pid
            elif full > 0:
                register = True
        try:
            fresh = self.pool.allocate_array(need - shared)
        except MemoryError:
            if shared:                    # unwind the shared references
                self.pool.release_array(row[:shared])
                self._pfx_sharers[pid] -= 1
                self.slot_pfx[slot] = -1
            row[:shared] = -1
            self._free_slots.append(slot)
            raise
        row[shared:need] = fresh
        if register:
            k = min(req.prefix_len // self.page_size, need)
            if k:
                self._pfx_pages[pid] = row[:k].copy()
                self._pfx_sharers[pid] = 1
                self.slot_pfx[slot] = pid

        self.slot_npages[slot] = need
        self.slot_tokens[slot, :len(stream)] = stream
        self.slot_len[slot] = 0
        self.slot_eff[slot] = len(stream)
        self.slot_base[slot] = len(req.prompt)
        self.slot_miss[slot] = True       # fresh mapping: first step walks
        self.slot_req[slot] = idx
        self.r_status[idx] = RUNNING
        self.r_admit_seq[idx] = self._admit_seq
        self._admit_seq += 1
        self.stats["admitted"] += 1
        if self.r_retries[idx]:
            self.stats["resumed"] += 1
        if self.meter is not None:
            self.meter.bind_slot(slot, req.req_id)
        if self.translation_budget is not None:
            est = float(self._estimate(np.asarray([idx]))[0])
            self.slot_est[slot] = est
            self._est_sum += est
        if self.num_running > self.stats["peak_running"]:
            self.stats["peak_running"] = self.num_running
        return slot

    # -- the step path (all vectorized) --------------------------------------
    def price_step(self) -> np.ndarray:
        """Price one engine step for every active slot under every
        mechanism at once, advance the dirty bits, and record the
        occupancy-driven table-mode decision.  Returns active slots."""
        act = np.flatnonzero(self.slot_req >= 0)
        self.stats["steps"] += 1
        mode = self.table_mode or self.preferred_mode()
        self.stats["mode_flat_steps" if mode == BT.FLAT
                   else "mode_radix_steps"] += 1
        if self.meter is not None and act.size:
            self.meter.record_slots(
                act, ~self.slot_miss[act], self.slot_pages[act],
                self.leaf_size, shared_leaves=self.prefix_sharing)
            self.slot_miss[act] = False
            if self.translation_budget is not None:
                i = self.meter.model.mechs.index(self.budget_mech)
                if float(self.meter.step_cycles[-1][i]) \
                        > self.translation_budget:
                    self._over_budget += 1
                    if self._over_budget >= self.budget_patience:
                        victim = self.pick_victim_slot()
                        if victim is not None:
                            self.preempt_slot(victim, reason="budget")
                        self._over_budget = 0
                else:
                    self._over_budget = 0
        return act

    def advance(self, out_tokens: np.ndarray) -> List[Request]:
        """Consume one decode output for every active slot: teacher-
        forced slots keep reading their stream, decode-phase slots
        append the produced token; finished requests retire (freeing
        pages first), then grown streams allocate their boundary pages
        (shedding victims on pool exhaustion).  Returns finished."""
        out = np.asarray(out_tokens)
        act = self.slot_req >= 0
        self.slot_len[act] += 1
        prod = act & (self.slot_len >= self.slot_eff)
        rows = np.flatnonzero(prod)
        finished: List[Request] = []
        if not rows.size:
            return finished
        self.slot_tokens[rows, self.slot_eff[rows]] = out[rows]
        self.slot_eff[rows] += 1
        gen = self.slot_eff[rows] - self.slot_base[rows]
        done_mask = gen >= self.r_max_new[self.slot_req[rows]]
        for b in rows[done_mask]:         # per-event: retirement
            finished.append(self._retire(int(b)))
        grow_rows = rows[~done_mask]
        needs = -(-self.slot_eff[grow_rows] // self.page_size)
        g = grow_rows[needs > self.slot_npages[grow_rows]]
        if g.size:
            while self.pool.free_pages < g.size:
                victim = self.pick_victim_slot()
                if victim is None:
                    raise MemoryError(
                        "KV pool exhausted with nothing left to shed")
                self.preempt_slot(victim, reason="overload")
                g = g[self.slot_req[g] >= 0]
                if not g.size:
                    break
        if g.size:
            fresh = self.pool.allocate_array(g.size)
            self.slot_pages[g, self.slot_npages[g]] = fresh
            self.slot_npages[g] += 1
            self.slot_miss[g] = True      # mapping grew: next step walks
        return finished

    # -- preemption / retirement (per-lifetime events) ------------------------
    def pick_victim_slot(self, prefer_not: Optional[int] = None
                         ) -> Optional[int]:
        """Vectorized :meth:`BatchScheduler.pick_victim`: lowest
        priority, latest admission breaking ties; ``prefer_not`` loses
        ties but never outranks a lower-priority runner."""
        run = np.flatnonzero(self.slot_req >= 0)
        if not run.size:
            return None
        ridx = self.slot_req[run]
        not_self = run != (-1 if prefer_not is None else prefer_not)
        order = np.lexsort((self.r_admit_seq[ridx], not_self,
                            -self.r_prio[ridx]))
        return int(run[order[-1]])

    def _copyout(self, slot: int, req: Request) -> None:
        req.generated = [int(t) for t in self.slot_tokens[
            slot, self.slot_base[slot]:self.slot_eff[slot]]]

    def _release_slot(self, slot: int) -> None:
        npg = int(self.slot_npages[slot])
        if npg:
            self.pool.release_array(self.slot_pages[slot, :npg])
            self.slot_pages[slot, :npg] = -1
        self.slot_npages[slot] = 0
        pid = int(self.slot_pfx[slot])
        if pid >= 0:
            self._pfx_sharers[pid] -= 1
            if self._pfx_sharers[pid] == 0:
                del self._pfx_sharers[pid]
                del self._pfx_pages[pid]
            self.slot_pfx[slot] = -1
        if self.translation_budget is not None:
            self._est_sum -= float(self.slot_est[slot])
            self.slot_est[slot] = 0.0
        self.slot_req[slot] = -1
        self.slot_miss[slot] = False
        self._free_slots.append(slot)

    def preempt_slot(self, slot: int, reason: str = "evict") -> Request:
        """Evict a running slot: tokens generated so far are preserved
        on the request (teacher-forced replay restores them bit-exactly
        at re-admission), pages release through the refcounts (a shared
        prefix page survives while any sharer lives), and the request
        requeues with exponential backoff — or is shed for good past
        ``max_retries``."""
        idx = int(self.slot_req[slot])
        req = self.reqs[idx]
        self._copyout(slot, req)
        self.r_eff[idx] = self.r_base[idx] + len(req.generated)
        self._release_slot(slot)
        self.stats["preempted"] += 1
        self.r_retries[idx] += 1
        req.retries = int(self.r_retries[idx])
        if req.retries > req.max_retries:
            self.stats["shed"] += 1
            if self.meter is not None:
                self.meter.release_slot(slot, retire=True)
            req.failed = "shed"
            self.r_status[idx] = FAILED
            self.failed.append(req)
        else:
            if self.meter is not None:
                self.meter.release_slot(slot, retire=False)
            req.not_before = self.clock + 2 ** req.retries
            self.r_not_before[idx] = req.not_before
            self.r_status[idx] = QUEUED
        resilience.log_event(
            "preempt", f"fleet slot {slot} req {req.req_id} ({reason}), "
                       f"retry {req.retries}/{req.max_retries}, "
                       f"{len(req.generated)} tokens kept")
        return req

    def _retire(self, slot: int) -> Request:
        idx = int(self.slot_req[slot])
        req = self.reqs[idx]
        self._copyout(slot, req)
        self.r_eff[idx] = self.r_base[idx] + len(req.generated)
        self._release_slot(slot)
        if self.meter is not None:
            self.meter.release_slot(slot, retire=True)
        self.r_status[idx] = DONE
        self.stats["completed"] += 1
        return req


class FleetEngine:
    """The fleet decode loop: one jitted surrogate decode over the full
    slot axis per step, scheduler bookkeeping fully vectorized around
    it.  API mirrors :class:`ServeEngine` (submit / run / throughput)."""

    def __init__(self, *, max_batch: int = 1024, max_len: int = 64,
                 page_size: int = 8, leaf_size: int = 4,
                 num_pages: Optional[int] = None, cost_model=None,
                 table_mode: Optional[str] = None,
                 prefix_sharing: bool = True,
                 translation_budget: Optional[float] = None,
                 budget_mech: str = "ndpage",
                 flatten_threshold: float = 0.5, vocab: int = VOCAB):
        meter = None
        if cost_model is not None:
            from repro.sim.cost_model import TranslationMeter
            meter = TranslationMeter(cost_model, max_slots=max_batch)
        self.meter = meter
        if num_pages is None:
            num_pages = max_batch * (-(-max_len // page_size)) + 8
        self.sched = FleetScheduler(
            num_pages=num_pages, max_batch=max_batch,
            page_size=page_size, max_len=max_len, leaf_size=leaf_size,
            flatten_threshold=flatten_threshold, table_mode=table_mode,
            meter=meter, prefix_sharing=prefix_sharing,
            translation_budget=translation_budget,
            budget_mech=budget_mech)
        self.max_batch = max_batch
        self._decode = _decode_fn(vocab)
        self._rows = np.arange(max_batch)

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def run(self, max_steps: int = 100_000) -> List[Request]:
        finished: List[Request] = []
        s = self.sched
        for _ in range(max_steps):
            s.tick()
            s.admit()
            if not s.num_running and not s.has_queued():
                break
            if not s.num_running:
                continue
            # injected mid-decode eviction (the evict_storm chaos
            # plan): teacher-forced replay keeps tokens bit-exact
            inj = resilience.fault_injector()
            if inj is not None and s.num_running and inj.fires("evict"):
                s.preempt_slot(s.pick_victim_slot(), reason="fault")
                if not s.num_running:
                    continue
            s.price_step()
            nxt = s.slot_tokens[self._rows,
                                np.minimum(s.slot_len, s.max_len - 1)]
            out = np.asarray(self._decode(jnp.asarray(nxt),
                                          jnp.asarray(s.slot_len)))
            finished.extend(s.advance(out))
        return finished

    def throughput(self) -> Dict:
        """Per-mechanism fleet report (requires ``cost_model``) — the
        ``ServeEngine.throughput`` contract plus fleet-scale fields
        (peak concurrency, scheduler stats, decode trace count)."""
        if self.meter is None:
            raise ValueError("FleetEngine was built without a cost_model;"
                             " pass cost_model= to enable throughput()")
        m = self.meter
        return {
            "tokens_per_sec": m.tokens_per_sec(),
            "translation_cycles": m.translation_cycles(),
            "per_step_cycles": m.per_step_cycles(),
            "tokens": m.tokens, "steps": m.steps,
            "tcache_hits": m.hits, "tcache_misses": m.misses,
            "peak_running": self.sched.stats["peak_running"],
            "occupancy": self.sched.occupancy(),
            "stats": dict(self.sched.stats),
            "prefix_sharing": self.sched.prefix_sharing,
            "decode_traces": decode_trace_count(),
        }
