"""Deprecated import path — the implementation lives in
``repro.serving._scheduler``; import :class:`BatchScheduler` /
:class:`Request` from :mod:`repro.serving` instead."""
import warnings

from repro.serving._scheduler import (BatchScheduler,  # noqa: F401
                                      Request)

warnings.warn(
    "repro.serving.scheduler is deprecated; import BatchScheduler / "
    "Request from repro.serving instead",
    DeprecationWarning, stacklevel=2)
