"""Continuous-batching scheduler over the paged KV manager.

The scheduler is the "OS" of the serving stack: it admits requests while
physical KV pages are available, allocates/frees pages through
KVPageManager, and — NDPage's runtime decision — picks the table
organization per step from measured occupancy (flat once occupancy crosses
the threshold, which for dense decode is immediately; radix only helps
sparse/prefix-shared mappings).  Table rows are memoized in the
TranslationCache (the PWC analogue) keyed by (seq, version); the cache
owns the version counters (bumped on mapping growth and on invalidate).

When the engine runs translation-costed (a
:class:`repro.sim.cost_model.TranslationMeter` is attached), every
``step_tables`` call also prices the step: a cache hit costs the
mechanism's TLB-hit cycles, a miss costs its walk plus the touched-PTE-
line surcharge of the rebuilt row — accumulated per step and per
request for ALL mechanisms at once (see cost_model docs).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager
from repro.core.translation_cache import TranslationCache


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray               # (S_prompt,) int32
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class BatchScheduler:
    def __init__(self, kvm: KVPageManager, max_batch: int,
                 table_mode: Optional[str] = None, meter=None):
        self.kvm = kvm
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch - 1, -1, -1))
        self.table_mode = table_mode          # None = occupancy-driven
        self.tcache = TranslationCache(capacity=4 * max_batch)
        #: optional repro.sim.cost_model.TranslationMeter — when set,
        #: every step's lookups are priced under all mechanisms
        self.meter = meter
        self.stats = {"admitted": 0, "completed": 0, "preempted": 0,
                      "steps": 0}

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _can_admit(self, req: Request) -> bool:
        need = -(-max(len(req.prompt), 1) // self.kvm.page_size) + 1
        return bool(self.free_slots) and self.kvm.pool.free_pages >= need

    def admit(self) -> List[Tuple[int, Request]]:
        """Admit queued requests into free slots; returns new (slot, req)."""
        admitted = []
        while self.queue and self._can_admit(self.queue[0]):
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            self.kvm.add_sequence(req.req_id, len(req.prompt))
            self.running[req.req_id] = req
            self.slot_of[req.req_id] = slot
            self.stats["admitted"] += 1
            admitted.append((slot, req))
        return admitted

    # -- step bookkeeping ----------------------------------------------------
    def active_seqs(self) -> List[int]:
        return sorted(self.running, key=lambda r: self.slot_of[r])

    def step_tables(self):
        """(mode, table rows per running seq, lengths) for the decode step."""
        mode = self.table_mode or self.kvm.preferred_mode()
        seqs = self.active_seqs()
        rows = []
        hits = np.zeros(len(seqs), bool)
        for i, sid in enumerate(seqs):
            row = self.tcache.lookup(sid)
            if row is None:
                pages = self.kvm.pages[sid]
                row = np.full(self.kvm.max_pages, -1, np.int32)
                row[: len(pages)] = pages
                self.tcache.insert(sid, None, row)
            else:
                hits[i] = True
            rows.append(row)
        lengths = np.asarray([self.kvm.lengths[s] for s in seqs], np.int32)
        self.stats["steps"] += 1
        stacked = (np.stack(rows) if rows
                   else np.zeros((0, self.kvm.max_pages), np.int32))
        if self.meter is not None and rows:
            # price the step: a hit is the TLB-hit analogue, a miss a
            # table walk whose cost scales with the touched PTE lines
            # of the rebuilt row under each mechanism's organization
            self.meter.record_step(seqs, hits, stacked,
                                   self.kvm.leaf_size)
        return mode, stacked, lengths

    def record_tokens(self, tokens: Dict[int, int]) -> List[Request]:
        """Append generated tokens; grow mappings; retire finished."""
        finished = []
        for sid, tok in tokens.items():
            req = self.running[sid]
            req.generated.append(int(tok))
            old_pages = len(self.kvm.pages[sid])
            self.kvm.append_token(sid)
            if len(self.kvm.pages[sid]) != old_pages:
                self.tcache.bump(sid)         # mapping changed
        for sid in list(self.running):
            if self.running[sid].done:
                req = self.running.pop(sid)
                slot = self.slot_of.pop(sid)
                self.free_slots.append(slot)
                self.kvm.free_sequence(sid)
                self.tcache.invalidate(sid)
                if self.meter is not None:
                    self.meter.retire_request(sid)
                self.stats["completed"] += 1
                finished.append(req)
        return finished
