"""ServeEngine: continuous-batching decode over the paged KV cache.

One unified step path: every live slot advances one token per engine step.
Slots still consuming their prompt are teacher-forced (the next prompt
token is fed regardless of the model's argmax); slots past their prompt
decode greedily.  Prompt feeding therefore exercises the exact same paged
append path as decoding — there is no separate prefill code to diverge.

Requests are admitted with ONE initial page; pages are allocated by the
scheduler as lengths grow (the OS role).  The kv table mode is either
pinned or occupancy-driven (the NDPage flatten decision).

Translation-costed mode: pass ``cost_model`` (a
:class:`repro.sim.cost_model.TranslationCostModel`) and every scheduler
step is priced under ALL simulated mechanisms at once — cache hits at
TLB-hit cost, misses at each mechanism's walk cost plus the touched-
PTE-line surcharge of the rebuilt row.  ONE decode loop serves every
mechanism (the mechanism never enters the jit, so nothing recompiles);
:meth:`ServeEngine.throughput` then reports tokens/sec per mechanism.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager
from repro.models import decode_step, init_decode_state
from repro.serving._scheduler import BatchScheduler, Request
from repro.util import resilience


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 256, page_size: int = 16,
                 table_mode: Optional[str] = None, cost_model=None):
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_len = max_len
        max_pages_total = max_batch * (-(-max_len // page_size)) + 8
        self.kvm = KVPageManager(max_pages_total, page_size, max_batch,
                                 max_len)
        self.meter = None
        if cost_model is not None:
            from repro.sim.cost_model import TranslationMeter
            self.meter = TranslationMeter(cost_model)
        self.sched = BatchScheduler(self.kvm, max_batch,
                                    table_mode=table_mode,
                                    meter=self.meter)
        self.max_batch = max_batch
        # the jit-side KV pools must cover every physical page id the
        # host allocator can hand out (ids at/past the pool corrupt KV
        # silently through clamped scatter)
        self.state = init_decode_state(cfg, max_batch, max_len,
                                       kv_mode=BT.FLAT, page_size=page_size,
                                       num_pages=max_pages_total)
        # per-slot prompt progress; _slot_prompt holds the stream being
        # teacher-forced (effective prompt snapshot taken at admission,
        # so a preempted request re-prefills prompt + prior tokens)
        self._prompt_pos = np.zeros(max_batch, np.int64)
        self._next_token = np.zeros(max_batch, np.int32)
        self._slot_prompt: List[Optional[np.ndarray]] = [None] * max_batch
        # inactive slots write their (discarded) K/V into a scratch page so
        # they can never alias a live sequence's pages
        self._scratch_page = self.kvm.pool.allocate(1)[0]

    # -- public ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            self.sched.tick()
            for slot, req in self.sched.admit():
                # pages for the whole effective prompt were mapped at
                # admission; teacher-force it from step 0 (for a resumed
                # request that replays prompt + generated-so-far, so the
                # KV cache is rebuilt bit-exactly before decode resumes)
                self._slot_prompt[slot] = req.effective_prompt()
                self._prompt_pos[slot] = 0
                self._next_token[slot] = int(self._slot_prompt[slot][0])
            if not self.sched.running and not self.sched.queue:
                break
            if not self.sched.running:
                continue
            finished.extend(self._engine_step())
        return finished

    def throughput(self) -> Dict:
        """Per-mechanism serving report (requires ``cost_model``):
        tokens/sec, accumulated translation cycles, the PER-STEP budget
        (mean/max over the meter's retained step window — misses make
        spiky steps), and the hit/miss tallies — one decode run priced
        under every mechanism."""
        if self.meter is None:
            raise ValueError("ServeEngine was built without a cost_model;"
                             " pass cost_model= to enable throughput()")
        m = self.meter
        return {
            "tokens_per_sec": m.tokens_per_sec(),
            "translation_cycles": m.translation_cycles(),
            "per_step_cycles": m.per_step_cycles(),
            "tokens": m.tokens, "steps": m.steps,
            "tcache_hits": m.hits, "tcache_misses": m.misses,
        }

    # -- internals --------------------------------------------------------------
    def _engine_step(self) -> List[Request]:
        # injected mid-decode eviction (the evict_storm chaos plan):
        # preempt the scheduler's victim of choice before the step runs;
        # greedy re-prefill makes the final tokens bit-exact anyway
        inj = resilience.fault_injector()
        if inj is not None and self.sched.running and inj.fires("evict"):
            self.sched.preempt(self.sched.pick_victim(), reason="fault")
            if not self.sched.running:
                return []
        mode, table, lens = self._build_tables()
        tokens = jnp.asarray(self._next_token)
        state = dict(self.state)
        state["table"] = table
        state["lengths"] = lens
        logits, new_state = decode_step(self.params, self.cfg, state,
                                        tokens, kv_mode=mode)
        self.state = dict(new_state)
        logits = np.asarray(logits)

        produced: Dict[int, int] = {}
        for sid in self.sched.active_seqs():
            slot = self.sched.slot_of[sid]
            self._prompt_pos[slot] += 1
            pos = self._prompt_pos[slot]
            stream = self._slot_prompt[slot]
            if pos < len(stream):
                # teacher-forced prompt consumption
                self._next_token[slot] = int(stream[pos])
            else:
                nxt = int(np.argmax(logits[slot]))
                self._next_token[slot] = nxt
                produced[sid] = nxt
        return self.sched.record_tokens(produced)

    def _build_tables(self):
        mode, rows, _ = self.sched.step_tables()
        flat = np.full((self.max_batch, self.kvm.max_pages),
                       self._scratch_page, np.int32)
        lens = np.zeros((self.max_batch,), np.int32)
        for row, sid in zip(rows, self.sched.active_seqs()):
            slot = self.sched.slot_of[sid]
            flat[slot] = row
            # the model writes the CURRENT token at cache index `lengths`;
            # exactly prompt_pos tokens are materialized (prompt_pos counts
            # every engine step this slot has taken)
            lens[slot] = int(self._prompt_pos[slot])
        table = jnp.asarray(flat)
        if mode == BT.RADIX:
            table = BT.radix_from_flat(
                table, leaf_size=min(16, self.kvm.max_pages))
        return mode, table, jnp.asarray(lens)


def greedy_reference(cfg, params, prompt: np.ndarray, new_tokens: int,
                     kv_mode: str = "dense", max_len: int = 256,
                     page_size: int = 16) -> List[int]:
    """Single-sequence greedy decode without the scheduler (oracle for
    engine tests)."""
    from repro.models import prefill
    logits, state = prefill(params, cfg, jnp.asarray(prompt[None]),
                            kv_mode=kv_mode, max_len=max_len,
                            page_size=page_size)
    out = []
    tok = int(np.argmax(np.asarray(logits)[0]))
    out.append(tok)
    for _ in range(new_tokens - 1):
        logits, state = decode_step(params, cfg, state,
                                    jnp.asarray([tok], np.int32),
                                    kv_mode=kv_mode)
        tok = int(np.argmax(np.asarray(logits)[0]))
        out.append(tok)
    return out
