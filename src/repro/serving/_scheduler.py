"""Continuous-batching scheduler over the paged KV manager.

The scheduler is the "OS" of the serving stack: it admits requests while
physical KV pages are available, allocates/frees pages through
KVPageManager, and — NDPage's runtime decision — picks the table
organization per step from measured occupancy (flat once occupancy crosses
the threshold, which for dense decode is immediately; radix only helps
sparse/prefix-shared mappings).  Table rows are memoized in the
TranslationCache (the PWC analogue) keyed by (seq, version); the cache
owns the version counters (bumped on mapping growth and on invalidate).

When the engine runs translation-costed (a
:class:`repro.sim.cost_model.TranslationMeter` is attached), every
``step_tables`` call also prices the step: a cache hit costs the
mechanism's TLB-hit cycles, a miss costs its walk plus the touched-PTE-
line surcharge of the rebuilt row — accumulated per step and per
request for ALL mechanisms at once (see cost_model docs).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.kv_page_manager import KVPageManager
from repro.core.translation_cache import TranslationCache


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray               # (S_prompt,) int32
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)
    #: higher wins admission and survives eviction longer; ties resolve
    #: to arrival order (admission) / latest arrival (eviction victim)
    priority: int = 0
    #: give up if not finished within this many scheduler clock ticks of
    #: submission (None = no deadline)
    deadline_steps: Optional[int] = None
    #: preemptions tolerated before the request is shed for good
    max_retries: int = 3
    #: prefix-sharing identity (fleet path): requests carrying the same
    #: ``prefix_id`` share their first ``prefix_len`` prompt tokens (a
    #: common system prompt) and the scheduler maps the fully-covered
    #: pages of that head to ONE refcounted physical allocation
    prefix_id: Optional[int] = None
    prefix_len: int = 0
    # -- runtime bookkeeping (scheduler-owned) -------------------------------
    retries: int = 0
    submit_tick: int = -1
    not_before: int = 0              # backoff gate for re-admission
    admit_seq: int = -1              # admission order (victim tie-break)
    failed: Optional[str] = None     # "shed" | "deadline" when given up

    @classmethod
    def build(cls, req_id: int, prompt, *, max_new_tokens: int = 32,
              priority: int = 0, deadline_steps: Optional[int] = None,
              max_retries: int = 3, prefix_id: Optional[int] = None,
              prefix_len: int = 0) -> "Request":
        """The public constructor: exactly the caller-owned fields,
        keyword-only.  Runtime bookkeeping (``submit_tick``,
        ``admit_seq``, ``retries``, ``not_before``, ``failed``) belongs
        to the scheduler — callers building requests this way can never
        poke it."""
        if prefix_id is not None and not (0 <= prefix_len <= len(prompt)):
            raise ValueError(
                f"prefix_len {prefix_len} outside prompt of {len(prompt)}")
        return cls(req_id=req_id,
                   prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=max_new_tokens, priority=priority,
                   deadline_steps=deadline_steps, max_retries=max_retries,
                   prefix_id=prefix_id, prefix_len=prefix_len)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def effective_prompt(self) -> np.ndarray:
        """The token stream to teacher-force at (re-)admission: the
        prompt plus everything generated before a preemption.  Greedy
        decode is deterministic, so re-prefilling this stream rebuilds
        the KV cache bit-exactly and the continuation matches the
        never-preempted run."""
        if not self.generated:
            return self.prompt
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.generated, np.int32)])


class BatchScheduler:
    #: bound on the given-up-requests history (the same reason the
    #: meter bounds its step/retired histories): under sustained
    #: shedding an unbounded ``failed`` list is a leak at fleet scale
    FAILED_HISTORY = 4096

    def __init__(self, kvm: KVPageManager, max_batch: int,
                 table_mode: Optional[str] = None, meter=None,
                 failed_history: int = FAILED_HISTORY):
        self.kvm = kvm
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch - 1, -1, -1))
        self.table_mode = table_mode          # None = occupancy-driven
        self.tcache = TranslationCache(capacity=4 * max_batch)
        #: optional repro.sim.cost_model.TranslationMeter — when set,
        #: every step's lookups are priced under all mechanisms
        self.meter = meter
        self.stats = {"admitted": 0, "completed": 0, "preempted": 0,
                      "shed": 0, "deadline_dropped": 0, "resumed": 0,
                      "steps": 0}
        #: engine-driven clock (one tick per engine loop iteration, even
        #: when nothing is running) — backoff and deadlines key off it
        self.clock = 0
        #: requests given up on (``req.failed`` says why) — a bounded
        #: deque: only the most recent ``failed_history`` are retained
        #: (``stats["shed"]``/``stats["deadline_dropped"]`` stay exact)
        self.failed: Deque[Request] = deque(maxlen=failed_history)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.submit_tick < 0:
            req.submit_tick = self.clock
        self.queue.append(req)

    def tick(self) -> None:
        """Advance the scheduler clock (the engine calls this once per
        loop iteration, running or not, so backoff gates and deadlines
        make progress even while the batch is empty)."""
        self.clock += 1

    def _can_admit(self, req: Request) -> bool:
        need = -(-max(len(req.effective_prompt()), 1)
                 // self.kvm.page_size) + 1
        return bool(self.free_slots) and self.kvm.pool.free_pages >= need

    def _next_admissible(self) -> Optional[Request]:
        """Highest-priority queued request whose backoff gate has
        opened; FIFO within a priority class (stable sort).  Expired
        deadlines are dropped here."""
        for req in list(self.queue):
            if (req.deadline_steps is not None
                    and self.clock - req.submit_tick > req.deadline_steps):
                self.queue.remove(req)
                req.failed = "deadline"
                self.failed.append(req)
                self.stats["deadline_dropped"] += 1
                self.tcache.invalidate(req.req_id)
                if self.meter is not None:
                    self.meter.retire_request(req.req_id)
        ready = [r for r in self.queue if r.not_before <= self.clock]
        if not ready:
            return None
        return max(ready, key=lambda r: r.priority)   # max() is stable

    def admit(self) -> List[Tuple[int, Request]]:
        """Admit queued requests into free slots; returns new (slot, req).

        Head-of-line blocking is per priority class: if the best
        eligible request does not fit, nothing behind it jumps the
        queue (no starvation of big requests)."""
        admitted = []
        while True:
            req = self._next_admissible()
            if req is None or not self._can_admit(req):
                break
            self.queue.remove(req)
            slot = self.free_slots.pop()
            self.kvm.add_sequence(req.req_id, len(req.effective_prompt()))
            self.running[req.req_id] = req
            self.slot_of[req.req_id] = slot
            req.admit_seq = self.stats["admitted"]
            self.stats["admitted"] += 1
            if req.retries:
                self.stats["resumed"] += 1
            admitted.append((slot, req))
        return admitted

    # -- preemption / shedding ----------------------------------------------
    def pick_victim(self, prefer_not: Optional[int] = None
                    ) -> Optional[int]:
        """The running seq to evict under pressure: lowest priority,
        latest admission breaking ties (oldest work is preserved).
        ``prefer_not`` (the seq asking for pages) loses priority ties
        but a genuinely lower-priority runner is ALWAYS the victim —
        growth must never evict a higher-priority sequence."""
        if not self.running:
            return None
        return max(self.running,
                   key=lambda s: (-self.running[s].priority,
                                  s != prefer_not,
                                  self.running[s].admit_seq))

    def preempt(self, seq_id: int, reason: str = "evict") -> Request:
        """Evict a running request: free its slot and KV pages,
        invalidate its translation-cache rows (version floor advances —
        a recycled id can never hit the stale mapping), and either
        requeue it with exponential backoff or shed it for good once
        ``max_retries`` is exhausted.  The meter keeps accumulating
        across preemptions (re-prefill translation work is real work)."""
        req = self.running.pop(seq_id)
        slot = self.slot_of.pop(seq_id)
        self.free_slots.append(slot)
        self.kvm.free_sequence(seq_id)
        self.tcache.invalidate(seq_id)
        self.stats["preempted"] += 1
        req.retries += 1
        if req.retries > req.max_retries:
            req.failed = "shed"
            self.failed.append(req)
            self.stats["shed"] += 1
            if self.meter is not None:
                self.meter.retire_request(seq_id)
        else:
            req.not_before = self.clock + 2 ** req.retries
            self.queue.append(req)
        from repro.util import resilience
        resilience.log_event(
            "preempt", f"seq {seq_id} ({reason}), retry {req.retries}"
                       f"/{req.max_retries}, "
                       f"{len(req.generated)} tokens kept")
        return req

    def grow(self, seq_id: int) -> bool:
        """Grow ``seq_id``'s mapping by one token, shedding the lowest-
        priority runner on pool exhaustion until the allocation fits.
        Returns False when ``seq_id`` itself was the victim of last
        resort (caller must stop touching its slot this step)."""
        while True:
            try:
                old_pages = len(self.kvm.pages[seq_id])
                self.kvm.append_token(seq_id)
                if len(self.kvm.pages[seq_id]) != old_pages:
                    self.tcache.bump(seq_id)     # mapping changed
                return True
            except MemoryError:
                victim = self.pick_victim(prefer_not=seq_id)
                if victim is None:
                    raise
                self.preempt(victim, reason="overload")
                if victim == seq_id:
                    return False

    # -- step bookkeeping ----------------------------------------------------
    def active_seqs(self) -> List[int]:
        return sorted(self.running, key=lambda r: self.slot_of[r])

    def step_tables(self):
        """(mode, table rows per running seq, lengths) for the decode step."""
        mode = self.table_mode or self.kvm.preferred_mode()
        seqs = self.active_seqs()
        rows = []
        hits = np.zeros(len(seqs), bool)
        for i, sid in enumerate(seqs):
            row = self.tcache.lookup(sid)
            if row is None:
                pages = self.kvm.pages[sid]
                row = np.full(self.kvm.max_pages, -1, np.int32)
                row[: len(pages)] = pages
                self.tcache.insert(sid, None, row)
            else:
                hits[i] = True
            rows.append(row)
        lengths = np.asarray([self.kvm.lengths[s] for s in seqs], np.int32)
        self.stats["steps"] += 1
        stacked = (np.stack(rows) if rows
                   else np.zeros((0, self.kvm.max_pages), np.int32))
        if self.meter is not None and rows:
            # price the step: a hit is the TLB-hit analogue, a miss a
            # table walk whose cost scales with the touched PTE lines
            # of the rebuilt row under each mechanism's organization
            self.meter.record_step(seqs, hits, stacked,
                                   self.kvm.leaf_size)
        return mode, stacked, lengths

    def record_tokens(self, tokens: Dict[int, int]) -> List[Request]:
        """Append generated tokens; grow mappings (shedding under
        overload); retire finished."""
        finished = []
        for sid, tok in tokens.items():
            if sid not in self.running:       # evicted earlier this step
                continue
            req = self.running[sid]
            req.generated.append(int(tok))
            if req.done:
                continue                      # retires below; no growth
            self.grow(sid)
        for sid in list(self.running):
            if self.running[sid].done:
                req = self.running.pop(sid)
                slot = self.slot_of.pop(sid)
                self.free_slots.append(slot)
                self.kvm.free_sequence(sid)
                self.tcache.invalidate(sid)
                if self.meter is not None:
                    self.meter.retire_request(sid)
                self.stats["completed"] += 1
                finished.append(req)
        return finished
