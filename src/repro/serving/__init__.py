from repro.serving.engine import ServeEngine  # noqa: F401
from repro.serving.scheduler import BatchScheduler, Request  # noqa: F401
