"""The serving layer's public import surface.

``ServeEngine`` / ``BatchScheduler`` run the real model at small batch;
``FleetEngine`` / ``FleetScheduler`` are the same serving design at
fleet scale (thousands of live sequences, struct-of-arrays scheduling,
prefix sharing, translation-aware admission).  Build requests with
:meth:`Request.build` — it owns the runtime-bookkeeping defaults.

Implementation modules are private (``_engine`` / ``_scheduler`` /
``fleet``); the old ``repro.serving.engine`` / ``repro.serving.
scheduler`` module paths remain as deprecation shims.
"""
from repro.serving._engine import (ServeEngine,  # noqa: F401
                                   greedy_reference)
from repro.serving._scheduler import (BatchScheduler,  # noqa: F401
                                      Request)
from repro.serving.fleet import (FleetEngine,  # noqa: F401
                                 FleetScheduler)
