"""Deprecated import path — the implementation lives in
``repro.serving._engine``; import :class:`ServeEngine` /
:func:`greedy_reference` from :mod:`repro.serving` instead."""
import warnings

from repro.serving._engine import (ServeEngine,  # noqa: F401
                                   greedy_reference)

warnings.warn(
    "repro.serving.engine is deprecated; import ServeEngine / "
    "greedy_reference from repro.serving instead",
    DeprecationWarning, stacklevel=2)
