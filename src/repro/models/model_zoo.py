"""Top-level model API: init / train forward / prefill / decode.

Entry points used by train/, serving/ and launch/:

  init_params(cfg, key)                      -> params pytree
  forward_train(params, cfg, batch)          -> (logits, aux_loss)
  init_decode_state(cfg, batch, max_len, kv_mode, page_size) -> state
  decode_step(params, cfg, state, tokens, kv_mode) -> (logits, state)
  prefill(params, cfg, tokens, ...)          -> (logits, state)

KV modes: "dense" | "paged_flat" (NDPage) | "paged_radix" (2-level baseline).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import config as C
from repro.core import block_table as BT
from repro.models import transformer as T
from repro.models.layers import (dtype_of, embed_init, dense_init, rmsnorm,
                                 rmsnorm_init, sinusoidal_positions)

Params = Dict[str, Any]

DEFAULT_PAGE_SIZE = 64


def build_model(name_or_cfg) -> C.ArchConfig:
    if isinstance(name_or_cfg, C.ArchConfig):
        return name_or_cfg
    return C.get_arch(name_or_cfg)


def _encoder_cfg(cfg: C.ArchConfig) -> C.ArchConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers,
        layer_pattern=((C.ATTN, C.DENSE_FF),), prefix_pattern=(),
        encoder_layers=0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: C.ArchConfig, key) -> Params:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "stack": T.stack_init(ks[1], cfg, cross=cfg.is_encdec),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size, dt)
    if cfg.is_encdec:
        ecfg = _encoder_cfg(cfg)
        params["encoder"] = T.stack_init(ks[3], ecfg, cross=False)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dt)
    return params


def _logits(params: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)
    if cfg.tie_embeddings:
        return (x @ params["embed"].T).astype(jnp.float32)
    return (x @ params["lm_head"]).astype(jnp.float32)


def _encode(params: Params, cfg, audio_frames: jnp.ndarray) -> jnp.ndarray:
    """Stub-frontend encoder: frames are precomputed embeddings (B, Se, D)."""
    ecfg = _encoder_cfg(cfg)
    se = audio_frames.shape[1]
    pos = sinusoidal_positions(se, cfg.d_model).astype(audio_frames.dtype)
    x = audio_frames + pos[None]
    x, _ = T.stack_apply_train(params["encoder"], x,
                               jnp.arange(se)[None], ecfg, causal=False)
    return rmsnorm(params["enc_norm"], x, cfg.rms_norm_eps)


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------
def forward_train(params: Params, cfg: C.ArchConfig, batch: Dict[str, Any]):
    """batch: tokens (B, S_tok) [+ audio_frames / vision_embeds stubs].

    Returns (logits (B, S, V) f32, aux_loss scalar).
    """
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.vision_tokens:
        vis = batch["vision_embeds"].astype(x.dtype)  # (B, Tv, D)
        x = jnp.concatenate([vis, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None]
    if cfg.rope_theta <= 0:  # sinusoidal-position archs (whisper)
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]

    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["audio_frames"])

    x, aux = T.stack_apply_train(params["stack"], x, positions, cfg,
                                 enc_out=enc_out, causal=True)
    return _logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_decode_state(cfg: C.ArchConfig, batch: int, max_len: int,
                      kv_mode: str = "dense",
                      page_size: int = DEFAULT_PAGE_SIZE,
                      table=None, num_pages: int | None = None
                      ) -> Dict[str, Any]:
    """Concrete zero-initialized decode state.

    For paged modes the default table is the identity pre-mapped layout
    (page p of seq b -> physical b*max_pages+p); the serving engine replaces
    it with KVPageManager-built tables.  ``num_pages`` sizes the physical
    KV pools (default ``batch * max_pages``); callers with a host-side
    page allocator MUST pass their pool size — a physical page id at or
    past the pool silently corrupts KV through clamped scatter/gather.
    """
    max_pages = -(-max_len // page_size)
    padded_len = max_pages * page_size
    pages_per_layer = (batch * max_pages if num_pages is None
                       else num_pages)
    state: Dict[str, Any] = {
        "lengths": jnp.zeros((batch,), jnp.int32),
        "stack": T.stack_init_state(cfg, batch, padded_len, kv_mode,
                                    page_size, pages_per_layer),
    }
    if kv_mode != "dense":
        if table is None:
            flat = jnp.arange(batch * max_pages, dtype=jnp.int32
                              ).reshape(batch, max_pages)
            table = (flat if kv_mode == BT.FLAT
                     else BT.radix_from_flat(
                         flat, leaf_size=min(16, max_pages)))
        state["table"] = table
    if cfg.is_encdec:
        state["enc_out"] = jnp.zeros(
            (batch, cfg.encoder_seq_len, cfg.d_model), dtype_of(cfg))
    return state


@functools.partial(jax.jit, static_argnames=("cfg", "kv_mode"))
def decode_step(params: Params, cfg: C.ArchConfig, state: Dict[str, Any],
                tokens: jnp.ndarray, kv_mode: str = "dense"):
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), state)."""
    lengths = state["lengths"]
    x = params["embed"][tokens][:, None, :]
    if cfg.rope_theta <= 0:
        # sinusoidal position embedding of the current index, per sequence
        d = cfg.d_model
        half = d // 2
        inv = 1.0 / (10_000 ** (jnp.arange(half) / max(half - 1, 1)))
        ang = lengths[:, None].astype(jnp.float32) * inv[None]
        pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pos_emb[:, None, :].astype(x.dtype)

    table = state.get("table")
    x, new_stack = T.stack_apply_decode(
        params["stack"], state["stack"], x, lengths, cfg,
        kv_mode=kv_mode, table=table, enc_out=state.get("enc_out"))
    logits = _logits(params, cfg, x)[:, 0]
    new_state = dict(state)
    new_state["stack"] = new_stack
    new_state["lengths"] = lengths + 1
    return logits, new_state


def prefill(params: Params, cfg: C.ArchConfig, tokens: jnp.ndarray,
            kv_mode: str = "dense", max_len: Optional[int] = None,
            page_size: int = DEFAULT_PAGE_SIZE, state=None,
            audio_frames=None):
    """Sequential prefill via decode_step scan (exercises the paged append
    path exactly as decode does).  tokens: (B, S_prompt)."""
    b, sp = tokens.shape
    max_len = max_len or (sp + 128)
    if state is None:
        state = init_decode_state(cfg, b, max_len, kv_mode, page_size)
    if cfg.is_encdec:
        assert audio_frames is not None
        state = dict(state)
        state["enc_out"] = _encode(params, cfg, audio_frames)

    def step(st, tok):
        logits, st = decode_step(params, cfg, st, tok, kv_mode)
        return st, logits

    state, logits_seq = jax.lax.scan(step, state, tokens.T)
    return logits_seq[-1], state
