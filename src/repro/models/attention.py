"""Attention variants: GQA/MQA/MHA, sliding-window, MLA, cross-attention.

Three execution regimes:
  * full/blockwise training & prefill (causal or windowed)
  * dense-cache decode (contiguous KV cache, the "ideal/no-translation" mode)
  * paged-cache decode lives in repro.serving / repro.kernels (NDPage path)

All softmax math in f32; blockwise (flash-style) attention is the default
above ``BLOCKWISE_THRESHOLD`` so 32k prefill never materializes S^2 scores.
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import block_table as BT
from repro.core import kv_page_manager as KVM
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init
from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]

BLOCKWISE_THRESHOLD = 2048
Q_CHUNK = 1024
KV_CHUNK = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def attn_init(key, cfg, dtype) -> Params:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, k * hd, dtype),
        "wv": dense_init(ks[2], d, k * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def mla_init(key, cfg, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, h * qk, dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], h * m.v_head_dim, d, dtype),
    }


# ---------------------------------------------------------------------------
# masked "naive" attention (short sequences, and the oracle for blockwise)
# ---------------------------------------------------------------------------
def _gqa_scores_attend(q, k, v, mask, scale):
    """q: (B,Sq,H,D) k,v: (B,Skv,K,D) mask: (B|1, Sq, Skv) bool."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    q = q.reshape(b, sq, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_offset=0, kv_valid_len=None) -> jnp.ndarray:
    """Masked softmax attention. q:(B,Sq,H,D), k/v:(B,Skv,K,D).

    ``q_offset``: absolute position of q[0] (decode / chunked prefill).
    ``window``: if >0, keys older than ``window`` positions are masked.
    ``kv_valid_len``: (B,) number of valid cache slots (decode).
    """
    b, sq = q.shape[:2]
    skv = k.shape[1]
    qpos = jnp.arange(sq) + q_offset                    # (Sq,)
    kpos = jnp.arange(skv)                              # (Skv,)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask = jnp.broadcast_to(mask[None], (b, sq, skv))
    if kv_valid_len is not None:
        mask &= kpos[None, None, :] < kv_valid_len[:, None, None]
    scale = 1.0 / math.sqrt(q.shape[-1])
    return _gqa_scores_attend(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention in pure JAX — memory O(chunk^2)
# ---------------------------------------------------------------------------
def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK
                        ) -> jnp.ndarray:
    """Online-softmax chunked attention (the pure-jnp flash oracle).

    q: (B,S,H,D), k/v: (B,S,K,D); self-attention with optional causal /
    sliding-window masking.  Never materializes more than
    (q_chunk x kv_chunk) scores per head.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    nq, nk = s // q_chunk, s // kv_chunk

    qc = q.reshape(b, nq, q_chunk, kh, g, d)
    kc = k.reshape(b, nk, kv_chunk, kh, d)
    vc = v.reshape(b, nk, kv_chunk, kh, d)

    qpos = jnp.arange(s).reshape(nq, q_chunk)
    kpos = jnp.arange(s).reshape(nk, kv_chunk)

    def q_block(qi, q_i):
        # q_i: (B, qc, K, G, D)
        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_chunk, d), jnp.float32)

        def kv_block(carry, inp):
            m, l, acc = carry
            kj, k_j, v_j = inp
            sc = jnp.einsum("bskgd,btkd->bkgst", q_i, k_j,
                            preferred_element_type=jnp.float32) * scale
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= kpos[kj][None, :] <= qpos[qi][:, None]
            if window > 0:
                msk &= kpos[kj][None, :] > qpos[qi][:, None] - window
            sc = jnp.where(msk[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            # guard fully-masked rows
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.where(jnp.isfinite(sc), p, 0.0)
            corr = jnp.exp(m - m_new)
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, K, G, D)

    out = jax.lax.map(lambda qi: q_block(qi, qc[:, qi]), jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, d)
    return out.astype(q.dtype)


def self_attention(q, k, v, *, causal: bool = True, window: int = 0
                   ) -> jnp.ndarray:
    if q.shape[1] > BLOCKWISE_THRESHOLD and q.shape[1] == k.shape[1]:
        # recompute-in-backward (flash-attention memory discipline): the
        # O(chunk^2) f32 score blocks are never stored as residuals —
        # only q/k/v are. On TPU the Pallas kernel implements the same.
        fn = jax.checkpoint(
            functools.partial(blockwise_attention, causal=causal,
                              window=window))
        return fn(q, k, v)
    return full_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# GQA layer: train / prefill
# ---------------------------------------------------------------------------
def attn_apply(params: Params, x: jnp.ndarray, positions: jnp.ndarray, cfg,
               *, window: int = 0, causal: bool = True,
               return_kv: bool = False):
    b, s, d = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kh, hd)
    v = (x @ params["wv"]).reshape(b, s, kh, hd)
    q = constrain_act(q, BATCH, None, "model", None)
    k = constrain_act(k, BATCH, None, "model", None)
    v = constrain_act(v, BATCH, None, "model", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = self_attention(q, k, v, causal=causal, window=window)
    out = constrain_act(out, BATCH, None, "model", None)
    y = out.reshape(b, s, h * hd) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


def cross_attn_apply(params: Params, x: jnp.ndarray,
                     enc_k: jnp.ndarray, enc_v: jnp.ndarray, cfg):
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    out = full_attention(q, enc_k, enc_v, causal=False)
    return out.reshape(b, s, h * hd) @ params["wo"]


def cross_kv(params: Params, enc_out: jnp.ndarray, cfg):
    b, se, _ = enc_out.shape
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ params["wk"]).reshape(b, se, kh, hd)
    v = (enc_out @ params["wv"]).reshape(b, se, kh, hd)
    return k, v


# ---------------------------------------------------------------------------
# GQA layer: dense-cache decode  (cache: (B, S_max, K, D))
# ---------------------------------------------------------------------------
def attn_decode_dense(params: Params, x: jnp.ndarray, cache_k, cache_v,
                      lengths: jnp.ndarray, cfg, *, window: int = 0):
    """One-token decode against a contiguous KV cache.

    x: (B, 1, D); lengths: (B,) tokens already in cache (the new token is
    written at index ``lengths``).  Returns (y, new_cache_k, new_cache_v).
    """
    b, s1, d = x.shape
    assert s1 == 1
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kh, hd)
    v = (x @ params["wv"]).reshape(b, 1, kh, hd)
    pos = lengths[:, None]                               # (B,1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, lengths].set(k[:, 0])
    cache_v = cache_v.at[bidx, lengths].set(v[:, 0])

    skv = cache_k.shape[1]
    kpos = jnp.arange(skv)
    mask = kpos[None, None, :] < (lengths + 1)[:, None, None]
    if window > 0:
        mask &= kpos[None, None, :] > lengths[:, None, None] - window
    out = _gqa_scores_attend(q, cache_k, cache_v, mask, 1.0 / math.sqrt(hd))
    y = out.reshape(b, 1, h * hd) @ params["wo"]
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# GQA layer: paged-cache decode (the NDPage path)
# ---------------------------------------------------------------------------
def attn_decode_paged(params: Params, x: jnp.ndarray, kp, vp, table,
                      lengths: jnp.ndarray, cfg, *, window: int = 0,
                      mode: str = BT.FLAT):
    """One-token decode against paged KV pools.

    kp/vp: (N_pages, page, K, D) pools; ``table`` is a flat (B, max_pages)
    map (NDPage) or a RadixTable (2-level baseline).  The table translate is
    the address-translation step; flat mode does ONE indirection, radix TWO.
    Returns (y, kp, vp).

    With a mesh installed (repro.parallel.context) the data path runs under
    shard_map with SHARD-LOCAL paging (perf iteration H4): sequences are
    scheduler-affine to their data shard, table values are local page ids,
    the pool gather never crosses shards, and only the small f32 score
    partials cross the model axis (head_dim-sharded pools).  Without a mesh
    the XLA reference path runs (CPU engine / smoke tests).
    """
    from repro.parallel.context import current_mesh

    b = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    page = kp.shape[1]
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kh, hd)
    v = (x @ params["wv"]).reshape(b, 1, kh, hd)
    pos = lengths[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    # translation (metadata path)
    phys_all = BT.translate_all(table, mode)              # (B, max_pages)

    mesh = current_mesh()
    if mesh is not None:
        out, kp, vp = _paged_attend_shardmap(
            mesh, q, k[:, 0], v[:, 0], kp, vp, phys_all, lengths,
            window=window, cfg=cfg)
    else:
        bidx = jnp.arange(b)
        logical = lengths // page
        phys_new = phys_all[bidx, logical]
        kp, vp = KVM.append_kv(kp, vp, k[:, 0], v[:, 0],
                               jnp.maximum(phys_new, 0), lengths % page)
        from repro.kernels import ops as KOPS
        out = KOPS.paged_attention(q, kp, vp, phys_all, lengths + 1,
                                   window=window)
    # contract (heads, head_dim) against wo without flattening so an
    # hd-sharded attention output psums once into (B, 1, D)
    wo3 = params["wo"].reshape(h, hd, cfg.d_model)
    y = jnp.einsum("bshd,hdD->bsD", out, wo3)
    return y, kp, vp


def _paged_attend_shardmap(mesh, q, k_new, v_new, kp, vp, phys_all, lengths,
                           *, window: int, cfg):
    """Shard-local paged append+attend (see attn_decode_paged docstring).

    q: (B,1,H,hd); k_new/v_new: (B,K,hd); kp/vp: (N,page,K,hd);
    phys_all: (B,maxp) SHARD-LOCAL page ids; lengths: (B,).
    Pools shard N->batch axes and hd->model; q/out shard hd->model.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import batch_axes

    import numpy as _np
    axes = batch_axes(mesh)
    dp_size = int(_np.prod([mesh.shape[a] for a in axes])) if axes else 1
    # sequences are shard-affine: only shard the batch if it divides (e.g.
    # long_500k's batch=1 keeps its pool whole and relies on the model axis)
    dp = axes if (axes and q.shape[0] % dp_size == 0) else None
    hd = q.shape[-1]
    n_model = mesh.shape["model"]
    page = kp.shape[1]
    h = q.shape[2]
    kh = kp.shape[2]

    # strategy choice (perf iteration H5): for MQA/small-K archs whose pool
    # fits per-device when replicated over the model axis, shard the QUERY
    # heads over "model" — each model shard runs softmax locally, zero score
    # psum.  Otherwise shard head_dim and psum the f32 score partials.
    # MUST agree with parallel.sharding's pool storage rule.
    from repro.parallel.sharding import _n_attn_layers, qhead_strategy
    kv_ok = kh % n_model == 0
    q_head_mode = (not kv_ok) and qhead_strategy(
        mesh, h=h, kh=kh, hd=hd, n_attn_layers=_n_attn_layers(cfg),
        n_pages=kp.shape[0], page=page)
    if q_head_mode:
        md = None
        qspec = P(dp, None, "model", None)
    else:
        md = "model" if hd % n_model == 0 else None
        qspec = P(dp, None, None, md)

    g_global = max(h // kh, 1)

    def local(q_l, kn_l, vn_l, kp_l, vp_l, tab_l, len_l, *,
              select_kv: bool = False):
        bl = q_l.shape[0]
        bidx = jnp.arange(bl)
        logical = len_l // page
        phys_new = jnp.maximum(tab_l[bidx, logical], 0)
        kp_l = kp_l.at[phys_new, len_l % page].set(kn_l)
        vp_l = vp_l.at[phys_new, len_l % page].set(vn_l)

        safe = jnp.maximum(tab_l, 0)
        maxp = tab_l.shape[1]
        kh_ = kp_l.shape[2]
        hdl = kp_l.shape[3]
        hq = q_l.shape[2]
        ks = kp_l[safe].reshape(bl, maxp * page, kh_, hdl)
        vs = vp_l[safe].reshape(bl, maxp * page, kh_, hdl)
        if select_kv:
            # q-head mode with grouped KV: pick each local q head's kv head
            # from the replicated pool (local heads may straddle groups)
            head_ids = jax.lax.axis_index("model") * hq + jnp.arange(hq)
            kv_ids = head_ids // g_global
            ks = ks[:, :, kv_ids, :]           # (bl, T, hq, hd)
            vs = vs[:, :, kv_ids, :]
            kh_, g = hq, 1
        else:
            g = max(hq // kh_, 1)
        qg = q_l.reshape(bl, 1, kh_, g, hdl)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                            preferred_element_type=jnp.float32)
        if not q_head_mode and md is not None:
            # partial over hd shards -> explicit small psum
            scores = jax.lax.psum(scores, "model")
        scores = scores / math.sqrt(hd)
        kpos = jnp.arange(maxp * page)
        valid = kpos[None, :] < (len_l + 1)[:, None]
        if window > 0:
            valid &= kpos[None, :] >= (len_l + 1 - window)[:, None]
        valid &= (tab_l >= 0).repeat(page, axis=1)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", w.astype(vs.dtype), vs,
                         preferred_element_type=jnp.float32)
        return (out.reshape(bl, 1, hq, hdl).astype(q_l.dtype), kp_l, vp_l)

    if kv_ok:
        # kv heads divide the model axis: shard K (and the aligned q-head
        # groups); attention fully local per shard
        in_specs = (P(dp, None, "model", None), P(dp, "model", None),
                    P(dp, "model", None), P(dp, None, "model", None),
                    P(dp, None, "model", None), P(dp, None), P(dp))
        out_specs = (P(dp, None, "model", None), P(dp, None, "model", None),
                     P(dp, None, "model", None))
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    elif q_head_mode:
        # query heads shard over "model": each shard sees ALL kv heads of
        # its sequences (pool replicated over model) and h/16 query heads
        def local_qh(q_l, kn_l, vn_l, kp_l, vp_l, tab_l, len_l):
            return local(q_l, kn_l, vn_l, kp_l, vp_l, tab_l, len_l,
                         select_kv=True)
        in_specs = (qspec, P(dp, None, None), P(dp, None, None),
                    P(dp, None, None, None), P(dp, None, None, None),
                    P(dp, None), P(dp))
        out_specs = (P(dp, None, "model", None), P(dp, None, None, None),
                     P(dp, None, None, None))
        fn = shard_map(local_qh, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    else:
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(qspec, P(dp, None, md), P(dp, None, md),
                      P(dp, None, None, md), P(dp, None, None, md),
                      P(dp, None), P(dp)),
            out_specs=(P(dp, None, None, md), P(dp, None, None, md),
                       P(dp, None, None, md)),
            check_rep=False)
    return fn(q, k_new, v_new, kp, vp, phys_all, lengths)


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
def _mla_qkv_full(params, x, positions, cfg):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.rms_norm_eps)
    q = (cq @ params["w_uq"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.rms_norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_apply(params: Params, x: jnp.ndarray, positions, cfg,
              *, causal: bool = True):
    """MLA train/prefill: expand latent to per-head K/V and attend."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_full(params, x, positions, cfg)
    q_nope = constrain_act(q_nope, BATCH, None, "model", None)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    k_nope = constrain_act(k_nope, BATCH, None, "model", None)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)
    v = constrain_act(v, BATCH, None, "model", None)
    # fold rope part into an extended head dim so one attention does both
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1)
    # pad v so self_attention's (K==V dim) contract works uniformly
    scale_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    out = self_attention(q_full * math.sqrt(out_scale(scale_dim, m)),
                         k_full, v_padded(v, k_full.shape[-1]),
                         causal=causal)
    out = out[..., : m.v_head_dim]
    return out.reshape(b, s, h * m.v_head_dim) @ params["wo"]


def out_scale(scale_dim: int, m) -> float:
    # self_attention scales by 1/sqrt(d) with d = padded dim; correct to
    # 1/sqrt(qk_dim)
    return 1.0


def v_padded(v, dim):
    pad = dim - v.shape[-1]
    if pad <= 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


def mla_decode(params: Params, x: jnp.ndarray, cache_ckv, cache_krope,
               lengths, cfg):
    """Absorbed-matrix MLA decode: attends in the 512-dim latent space.

    cache_ckv: (B, S_max, kv_lora); cache_krope: (B, S_max, rope_dim).
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    pos = lengths[:, None]
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.rms_norm_eps)
    q = (cq @ params["w_uq"]).reshape(
        b, 1, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    dkv = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.rms_norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]

    bidx = jnp.arange(b)
    cache_ckv = cache_ckv.at[bidx, lengths].set(c_kv[:, 0])
    cache_krope = cache_krope.at[bidx, lengths].set(k_rope[:, 0])

    # absorb W_uk into q:  q_lat (B,1,H,kv_lora)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,chn->bshc", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    scores = (
        jnp.einsum("bshc,btc->bhst", q_lat,
                   cache_ckv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                     cache_krope.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    ) / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    skv = cache_ckv.shape[1]
    mask = jnp.arange(skv)[None, None, None, :] < (lengths + 1)[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btc->bshc", w, cache_ckv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshc,chv->bshv", ctx, w_uv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    y = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype) @ params["wo"]
    return y, cache_ckv, cache_krope
