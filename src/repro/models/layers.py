"""Primitive layers: norms, embeddings, rope, FFNs.

Everything is a pure function over a params pytree (nested dicts of
jnp arrays).  Initializers take an explicit PRNG key and return params in the
config's dtype (master/compute dtype policies live in train/).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if theta <= 0:  # archs without rope (whisper)
        return x
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                           # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (no rope archs)."""
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10_000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------
def ffn_init(key, d_model: int, d_ff: int, dtype, gated: bool) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn_apply(params: Params, x: jnp.ndarray, gated: bool) -> jnp.ndarray:
    up = constrain_act(x @ params["w_up"], BATCH, None, "model")
    if gated:
        gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        h = (gate * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_down"]


def relu_sq_ffn_init(key, d_model: int, d_ff: int, dtype) -> Params:
    """RWKV channel-mix: relu(x W_k)^2 W_v with token-shift mixing."""
    ks = jax.random.split(key, 3)
    return {"w_k": dense_init(ks[0], d_model, d_ff, dtype),
            "w_v": dense_init(ks[1], d_ff, d_model, dtype),
            "mix_k": jnp.full((d_model,), 0.5, dtype=dtype)}


def relu_sq_ffn_apply(params: Params, x: jnp.ndarray,
                      x_prev: jnp.ndarray) -> jnp.ndarray:
    mix = params["mix_k"].astype(jnp.float32)
    xk = (x.astype(jnp.float32) * mix
          + x_prev.astype(jnp.float32) * (1 - mix)).astype(x.dtype)
    h = jnp.square(jax.nn.relu((xk @ params["w_k"]).astype(jnp.float32)))
    return h.astype(x.dtype) @ params["w_v"]
