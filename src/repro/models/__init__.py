from repro.models.model_zoo import (  # noqa: F401
    build_model,
    init_params,
    init_decode_state,
    forward_train,
    decode_step,
    prefill,
)
