"""Checkpointed chunked time scans for recurrent blocks (Mamba / RWKV).

``chunked_scan`` runs a per-timestep recurrence over S steps as an outer
lax.scan over S/chunk chunks whose body is wrapped in jax.checkpoint: the
backward pass stores only chunk-boundary carries and recomputes inside each
chunk, bounding activation memory at O(chunk) instead of O(S).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 256


def chunked_scan(step: Callable, carry, xs, *, chunk: int = DEFAULT_CHUNK,
                 checkpoint: bool = True) -> Tuple[Any, Any]:
    """Like lax.scan(step, carry, xs) with chunk-level remat.

    xs: pytree with leading time axis S (must divide by chunk after padding
    is handled by the caller).
    """
    s = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if s <= chunk:
        return jax.lax.scan(step, carry, xs)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def chunk_body(c, x_chunk):
        return jax.lax.scan(step, c, x_chunk)

    if checkpoint:
        chunk_body = jax.checkpoint(chunk_body)
    carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((s,) + a.shape[2:]), ys)
    return carry, ys
