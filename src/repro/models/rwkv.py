"""RWKV6 (Finch) time-mix block — data-dependent decay, attention-free.

Per head (head_size hs): matrix-valued state S in R^{hs x hs}:
    a_t   = k_t v_t^T                      (outer product)
    o_t   = r_t (S_t + diag(u) a_t)
    S_t+1 = diag(w_t) S_t + a_t
with w_t = exp(-exp(w0 + lora(x_t))) data-dependent per channel (the
headline RWKV6 feature).  Token-shift mixing feeds x_{t-1} into the r/k/v/g/w
projections.  State per layer: (wkv (B,H,hs,hs) f32, shift (B,D)).

Train/prefill uses chunk-checkpointed lax.scan; decode is O(1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.scan_utils import chunked_scan
from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]


def _dims(cfg):
    hs = cfg.rwkv.head_size
    nh = cfg.d_model // hs
    return nh, hs


def rwkv_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    nh, hs = _dims(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 10)
    return {
        "w_r": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_g": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype),
        # token-shift static mixes for r,k,v,g,w
        "mix": jnp.full((5, d), 0.5, dtype),
        # data-dependent decay lora: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.zeros((d,), jnp.float32) - 0.5,
        "w_A": dense_init(ks[5], d, r.decay_lora, dtype),
        "w_B": dense_init(ks[6], r.decay_lora, d, dtype,
                          scale=1.0 / math.sqrt(r.decay_lora)),
        "u": (jax.random.normal(ks[7], (nh, hs), jnp.float32) * 0.1),
        # per-head output groupnorm
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
    }


def _projections(params: Params, x: jnp.ndarray, x_prev: jnp.ndarray, cfg):
    """Token-shift mix then project. x, x_prev: (..., D)."""
    mix = params["mix"].astype(jnp.float32)
    xf, pf = x.astype(jnp.float32), x_prev.astype(jnp.float32)

    def mixed(i):
        return (xf * mix[i] + pf * (1 - mix[i])).astype(x.dtype)

    r = mixed(0) @ params["w_r"]
    k = mixed(1) @ params["w_k"]
    v = mixed(2) @ params["w_v"]
    g = mixed(3) @ params["w_g"]
    dec = jnp.tanh((mixed(4) @ params["w_A"]).astype(jnp.float32))
    dec = dec @ params["w_B"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(params["w0"] + dec))           # (..., D) in (0,1)
    return r, k, v, g, w


def _groupnorm_heads(params: Params, o: jnp.ndarray, nh: int, hs: int,
                     eps: float = 64e-5) -> jnp.ndarray:
    """Per-head layernorm of the wkv output. o: (..., D) f32."""
    shp = o.shape
    oh = o.reshape(shp[:-1] + (nh, hs))
    mu = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + eps)
    o = oh.reshape(shp)
    return o * params["ln_x_scale"] + params["ln_x_bias"]


def rwkv_apply(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Train/prefill. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    nh, hs = _dims(cfg)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _projections(params, x, x_prev, cfg)

    rh = constrain_act(r.reshape(b, s, nh, hs).astype(jnp.float32),
                       BATCH, None, "model", None)
    kh = constrain_act(k.reshape(b, s, nh, hs).astype(jnp.float32),
                       BATCH, None, "model", None)
    vh = constrain_act(v.reshape(b, s, nh, hs).astype(jnp.float32),
                       BATCH, None, "model", None)
    wh = constrain_act(w.reshape(b, s, nh, hs), BATCH, None, "model", None)
    u = params["u"]                                     # (H, hs)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,H,hs) each
        a_t = k_t[..., :, None] * v_t[..., None, :]     # (B,H,hs,hs)
        o_t = jnp.einsum("bhi,bhij->bhj", r_t,
                         state + u[None, :, :, None] * a_t)
        state = w_t[..., :, None] * state + a_t
        return state, o_t

    s0 = jnp.zeros((b, nh, hs, hs), jnp.float32)
    xs = (rh.swapaxes(0, 1), kh.swapaxes(0, 1), vh.swapaxes(0, 1),
          wh.swapaxes(0, 1))
    _, os_ = chunked_scan(step, s0, xs, checkpoint=cfg.remat)
    o = os_.swapaxes(0, 1).reshape(b, s, d)             # f32
    o = _groupnorm_heads(params, o, nh, hs)
    o = o * jax.nn.silu(g.astype(jnp.float32))
    return o.astype(x.dtype) @ params["w_o"]


def rwkv_init_state(cfg, batch: int):
    nh, hs = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, nh, hs, hs), jnp.float32),
        "shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def rwkv_decode(params: Params, x: jnp.ndarray, state, cfg):
    """One-token decode. x: (B, 1, D)."""
    b, _, d = x.shape
    nh, hs = _dims(cfg)
    x_t = x[:, 0]
    r, k, v, g, w = _projections(params, x_t,
                                 state["shift"].astype(x.dtype), cfg)
    rh = r.reshape(b, nh, hs).astype(jnp.float32)
    kh = k.reshape(b, nh, hs).astype(jnp.float32)
    vh = v.reshape(b, nh, hs).astype(jnp.float32)
    wh = w.reshape(b, nh, hs)
    u = params["u"]
    a = kh[..., :, None] * vh[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", rh,
                   state["wkv"] + u[None, :, :, None] * a)
    new_wkv = wh[..., :, None] * state["wkv"] + a
    o = o.reshape(b, d)
    o = _groupnorm_heads(params, o, nh, hs)
    o = o * jax.nn.silu(g.astype(jnp.float32))
    out = (o.astype(x.dtype) @ params["w_o"])[:, None]
    return out, {"wkv": new_wkv,
                 "shift": x_t.astype(jnp.float32)}
