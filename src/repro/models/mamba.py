"""Mamba (selective SSM) block — Jamba's attention-free mixer.

Faithful Mamba-1 selective scan:
  x, z = split(in_proj(u));  x = silu(causal_depthwise_conv(x))
  dt, B, C = x_proj(x);  dt = softplus(dt_proj(dt))
  h_t = exp(dt A) h_{t-1} + dt B x_t ;  y_t = C h_t + D x_t
  out = out_proj(y * silu(z))

The time recurrence uses chunk-checkpointed lax.scan (O(chunk) activation
memory); decode is the O(1) single-step update.  State = (conv_state
(B, d_in, d_conv-1), ssm_state (B, d_in, N)).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.scan_utils import chunked_scan
from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]


def _dims(cfg):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_in, dt_rank


def mamba_init(key, cfg, dtype) -> Params:
    mc, d_in, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_in, mc.d_conv), jnp.float32)
                   * (1.0 / math.sqrt(mc.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * mc.d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, dtype,
                              scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (d_in,), jnp.float32) * (math.log(0.1) - math.log(1e-3))
                + math.log(1e-3)), 1e-4, None))),
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], d_in, d, dtype),
    }


def _ssm_inputs(params: Params, x: jnp.ndarray, cfg):
    """x: (B, S, d_in) post-conv. Returns dt (f32), B, C, A."""
    mc, d_in, dt_rank = _dims(cfg)
    proj = x @ params["x_proj"]
    dt, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + mc.d_state],
                                 axis=-1)
    dt = jax.nn.softplus(
        (dt @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))        # (B,S,d_in)
    a = -jnp.exp(params["A_log"])                       # (d_in, N)
    return dt, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32), a


def _conv_full(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Causal depthwise conv along time. x: (B, S, d_in)."""
    mc, d_in, _ = _dims(cfg)
    w = params["conv_w"].astype(jnp.float32)            # (d_in, K)
    xt = x.astype(jnp.float32).transpose(0, 2, 1)       # (B, d_in, S)
    out = jax.lax.conv_general_dilated(
        xt[:, :, None, :], w[:, None, None, :],
        window_strides=(1, 1), padding=((0, 0), (mc.d_conv - 1, 0)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=d_in)[:, :, 0, :]
    out = out + params["conv_b"].astype(jnp.float32)[None, :, None]
    return jax.nn.silu(out).transpose(0, 2, 1).astype(x.dtype)


def mamba_apply(params: Params, u: jnp.ndarray, cfg) -> jnp.ndarray:
    """Train/prefill forward. u: (B, S, D) -> (B, S, D)."""
    mc, d_in, _ = _dims(cfg)
    b, s, d = u.shape
    xz = constrain_act(u @ params["in_proj"], BATCH, None, "model")
    x, z = jnp.split(xz, 2, axis=-1)
    x = _conv_full(params, x, cfg)
    x = constrain_act(x, BATCH, None, "model")
    dt, bm, cm, a = _ssm_inputs(params, x, cfg)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                       # (B,d_in),(B,d_in),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a[None])         # (B, d_in, N)
        dbx = (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((b, d_in, mc.d_state), jnp.float32)
    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          bm.swapaxes(0, 1), cm.swapaxes(0, 1))
    _, ys = chunked_scan(step, h0, xs, checkpoint=cfg.remat)
    y = ys.swapaxes(0, 1)                               # (B, S, d_in)
    y = y + params["D"][None, None] * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(u.dtype) @ params["out_proj"]


def mamba_init_state(cfg, batch: int):
    mc, d_in, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_in, mc.d_conv - 1), jnp.float32),
        "ssm": jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
    }


def mamba_decode(params: Params, u: jnp.ndarray, state, cfg):
    """One-token decode. u: (B, 1, D)."""
    mc, d_in, _ = _dims(cfg)
    b = u.shape[0]
    xz = u[:, 0] @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                    # (B, d_in)

    conv = state["conv"]                                # (B, d_in, K-1)
    window = jnp.concatenate([conv, x.astype(jnp.float32)[..., None]],
                             axis=-1)
    w = params["conv_w"].astype(jnp.float32)
    xc = jnp.einsum("bdk,dk->bd", window, w) + params["conv_b"].astype(
        jnp.float32)
    xc = jax.nn.silu(xc).astype(u.dtype)
    new_conv = window[..., 1:]

    dt, bm, cm, a = _ssm_inputs(params, xc[:, None], cfg)
    dt, bm, cm = dt[:, 0], bm[:, 0], cm[:, 0]
    da = jnp.exp(dt[..., None] * a[None])
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bm[:, None, :]
    h = da * state["ssm"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, cm)
    y = y + params["D"][None] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(u.dtype) @ params["out_proj"]
    return out[:, None], {"conv": new_conv, "ssm": h}
