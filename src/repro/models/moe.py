"""Routed mixture-of-experts FFN (GShard-style grouped capacity dispatch).

Tokens are processed in fixed-size groups so the dispatch one-hots stay
O(group * E * C) instead of O(T^2) — this is what makes MoE shardable and
memory-bounded at 1M-token batches.  Experts shard over the `model` mesh
axis (expert parallelism); the dispatch einsums lower to all-to-alls under
pjit when tokens are data-sharded.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]

GROUP_SIZE = 512  # tokens per routing group


def moe_init(key, cfg, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    dff = m.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)

    def experts(k, d_in, d_out, n):
        return (jax.random.normal(k, (n, d_in, d_out), jnp.float32)
                * (1.0 / math.sqrt(d_in))).astype(dtype)

    p: Params = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32, scale),
        "w_up": experts(ks[1], d, dff, m.num_experts),
        "w_down": experts(ks[2], dff, d, m.num_experts),
    }
    if cfg.gated_ffn:
        p["w_gate"] = experts(ks[3], d, dff, m.num_experts)
    if m.num_shared_experts:
        sh = {}
        kk = jax.random.split(ks[4], 3)
        sdff = dff * m.num_shared_experts
        sh["w_up"] = dense_init(kk[0], d, sdff, dtype)
        sh["w_down"] = dense_init(kk[1], sdff, d, dtype)
        if cfg.gated_ffn:
            sh["w_gate"] = dense_init(kk[2], d, sdff, dtype)
        p["shared"] = sh
    return p


def _expert_ffn(p: Params, x: jnp.ndarray, gated: bool) -> jnp.ndarray:
    """x: (E, C, D) -> (E, C, D) with per-expert weights (E, D, F)."""
    up = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    if gated:
        gate = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", x, p["w_gate"]).astype(jnp.float32))
        h = (gate * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _route_group(params: Params, xg: jnp.ndarray, cfg
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One routing group. xg: (G, D) -> (out (G, D), aux loss scalar)."""
    m = cfg.moe
    g, d = xg.shape
    e, k = m.num_experts, m.num_experts_per_tok
    # small groups (decode steps, smoke tests): exact dropless capacity;
    # large groups: capacity-factor routing (standard GShard behaviour)
    cap = g if g <= 64 else max(1, int(g * k * m.capacity_factor / e))

    logits = (xg.astype(jnp.float32) @ params["router"])          # (G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # (G, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)          # (G, k, E)
    tokens_per_e = onehot.sum(axis=(0, 1)) / (g * k)
    probs_per_e = probs.mean(axis=0)
    aux = e * jnp.sum(tokens_per_e * probs_per_e)

    # capacity assignment: position of each (token, slot) in its expert queue
    flat = onehot.reshape(g * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                    # (G*k, E)
    pos = (pos_in_e * flat).sum(-1).reshape(g, k)                 # (G, k)
    keep = (pos < cap) & (top_p > 0)
    pos = jnp.minimum(pos, cap - 1)

    # dispatch/combine tensors (G, E, C)
    disp = (jax.nn.one_hot(top_i, e, dtype=xg.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=xg.dtype)[..., None, :]
            * keep[..., None, None].astype(xg.dtype))             # (G,k,E,C)
    combine = disp.astype(jnp.float32) * top_p[..., None, None]
    disp = disp.sum(1)                                            # (G, E, C)
    combine = combine.sum(1)                                      # (G, E, C)

    expert_in = jnp.einsum("gec,gd->ecd", disp, xg)               # (E, C, D)
    expert_in = constrain_act(expert_in, "model", None, None)     # EP
    expert_out = _expert_ffn(params, expert_in, cfg.gated_ffn)
    expert_out = constrain_act(expert_out, "model", None, None)
    out = jnp.einsum("gec,ecd->gd", combine.astype(xg.dtype), expert_out)

    if m.num_shared_experts:
        sh = params["shared"]
        up = xg @ sh["w_up"]
        if cfg.gated_ffn:
            gate = jax.nn.silu((xg @ sh["w_gate"]).astype(jnp.float32))
            h = (gate * up.astype(jnp.float32)).astype(xg.dtype)
        else:
            h = jax.nn.gelu(up.astype(jnp.float32)).astype(xg.dtype)
        out = out + h @ sh["w_down"]
    return out, aux


def moe_apply(params: Params, x: jnp.ndarray, cfg
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss). Groups along the flattened tokens."""
    b, s, d = x.shape
    t = b * s
    gsz = min(GROUP_SIZE, t)
    ng = t // gsz
    assert t % gsz == 0, (t, gsz)
    xg = x.reshape(ng, gsz, d)

    def body(_, xi):
        return None, _route_group(params, xi, cfg)

    if ng == 1:
        out, aux = _route_group(params, xg[0], cfg)
        return out.reshape(b, s, d), aux
    _, (outs, auxs) = jax.lax.scan(body, None, xg)
    return outs.reshape(b, s, d), auxs.mean()
