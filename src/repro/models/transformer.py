"""Block + stack assembly with scan-over-periods.

A stack = ``prefix`` blocks (unrolled) + N periods of ``layer_pattern``
(lax.scan over stacked params) — HLO size stays O(period), not O(depth),
which keeps 60-88 layer archs compilable in bounded time/memory.

Decode state mirrors the params tree: {"prefix": [block_state...],
"scan": period_state stacked over periods}.  KV backends:
  dense       contiguous per-layer KV cache (the no-translation baseline)
  paged_flat  NDPage flattened single-level block table (one indirection)
  paged_radix 2-level directory->leaf block table (two indirections)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import config as C
from repro.core import block_table as BT
from repro.core import kv_page_manager as KVM
from repro.models import attention as A
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv as R
from repro.models.layers import (dtype_of, ffn_apply, ffn_init,
                                 relu_sq_ffn_apply, relu_sq_ffn_init,
                                 rmsnorm, rmsnorm_init)
from repro.parallel.context import BATCH, constrain_act

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def block_init(key, cfg, mixer_kind: str, ffn_kind: str,
               cross: bool = False) -> Params:
    dt = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": rmsnorm_init(d, dt), "norm2": rmsnorm_init(d, dt)}
    if mixer_kind in (C.ATTN, C.ATTN_LOCAL):
        p["mixer"] = A.attn_init(ks[0], cfg, dt)
    elif mixer_kind == C.ATTN_MLA:
        p["mixer"] = A.mla_init(ks[0], cfg, dt)
    elif mixer_kind == C.MAMBA:
        p["mixer"] = M.mamba_init(ks[0], cfg, dt)
    elif mixer_kind == C.RWKV:
        p["mixer"] = R.rwkv_init(ks[0], cfg, dt)
    else:
        raise ValueError(mixer_kind)
    if ffn_kind == C.MOE_FF:
        p["ffn"] = MOE.moe_init(ks[1], cfg, dt)
    elif cfg.rwkv is not None:
        p["ffn"] = relu_sq_ffn_init(ks[1], d, cfg.d_ff, dt)
    else:
        p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dt, cfg.gated_ffn)
    if cross:
        p["norm_cross"] = rmsnorm_init(d, dt)
        p["cross"] = A.attn_init(ks[2], cfg, dt)
    return p


def _apply_ffn(bp: Params, h: jnp.ndarray, cfg, ffn_kind: str,
               shift_prev: Optional[jnp.ndarray] = None):
    """Returns (y, aux)."""
    if ffn_kind == C.MOE_FF:
        return MOE.moe_apply(bp["ffn"], h, cfg)
    if cfg.rwkv is not None:
        if shift_prev is None:  # train: shift along seq
            prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        else:
            prev = shift_prev[:, None].astype(h.dtype)
        return relu_sq_ffn_apply(bp["ffn"], h, prev), jnp.float32(0)
    return ffn_apply(bp["ffn"], h, cfg.gated_ffn), jnp.float32(0)


def block_apply_train(bp: Params, x: jnp.ndarray, positions, cfg,
                      mixer_kind: str, ffn_kind: str,
                      enc_out=None, causal: bool = True):
    x = constrain_act(x, BATCH, None, None)
    h = rmsnorm(bp["norm1"], x, cfg.rms_norm_eps)
    if mixer_kind == C.ATTN:
        y = A.attn_apply(bp["mixer"], h, positions, cfg, causal=causal)
    elif mixer_kind == C.ATTN_LOCAL:
        y = A.attn_apply(bp["mixer"], h, positions, cfg,
                         window=cfg.window_size, causal=causal)
    elif mixer_kind == C.ATTN_MLA:
        y = A.mla_apply(bp["mixer"], h, positions, cfg, causal=causal)
    elif mixer_kind == C.MAMBA:
        y = M.mamba_apply(bp["mixer"], h, cfg)
    elif mixer_kind == C.RWKV:
        y = R.rwkv_apply(bp["mixer"], h, cfg)
    else:
        raise ValueError(mixer_kind)
    x = constrain_act(x + y, BATCH, None, None)
    if enc_out is not None and "cross" in bp:
        hc = rmsnorm(bp["norm_cross"], x, cfg.rms_norm_eps)
        ek, ev = A.cross_kv(bp["cross"], enc_out, cfg)
        x = x + A.cross_attn_apply(bp["cross"], hc, ek, ev, cfg)
    h2 = rmsnorm(bp["norm2"], x, cfg.rms_norm_eps)
    y2, aux = _apply_ffn(bp, h2, cfg, ffn_kind)
    return constrain_act(x + y2, BATCH, None, None), aux


# ---------------------------------------------------------------------------
# decode state per block
# ---------------------------------------------------------------------------
def block_init_state(cfg, mixer_kind: str, ffn_kind: str, batch: int,
                     max_len: int, kv_mode: str, page_size: int,
                     pages_per_layer: int):
    dt = dtype_of(cfg)
    st: Dict[str, Any] = {}
    if mixer_kind in (C.ATTN, C.ATTN_LOCAL):
        k, hd = cfg.num_kv_heads, cfg.head_dim
        if kv_mode == "dense":
            st["k"] = jnp.zeros((batch, max_len, k, hd), dt)
            st["v"] = jnp.zeros((batch, max_len, k, hd), dt)
        else:
            st["kp"] = jnp.zeros((pages_per_layer, page_size, k, hd), dt)
            st["vp"] = jnp.zeros((pages_per_layer, page_size, k, hd), dt)
    elif mixer_kind == C.ATTN_MLA:
        m = cfg.mla
        st["ckv"] = jnp.zeros((batch, max_len, m.kv_lora_rank), dt)
        st["kr"] = jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt)
    elif mixer_kind == C.MAMBA:
        st.update(M.mamba_init_state(cfg, batch))
    elif mixer_kind == C.RWKV:
        st.update(R.rwkv_init_state(cfg, batch))
    if cfg.rwkv is not None and ffn_kind == C.DENSE_FF:
        st["ffn_shift"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return st


def block_apply_decode(bp: Params, st, x, lengths, cfg,
                       mixer_kind: str, ffn_kind: str, kv_mode: str,
                       table=None, enc_out=None):
    """x: (B,1,D). Returns (x', new_state, aux)."""
    x = constrain_act(x, BATCH, None, None)
    h = rmsnorm(bp["norm1"], x, cfg.rms_norm_eps)
    new_st = dict(st)
    if mixer_kind in (C.ATTN, C.ATTN_LOCAL):
        window = cfg.window_size if mixer_kind == C.ATTN_LOCAL else 0
        if kv_mode == "dense":
            y, ck, cv = A.attn_decode_dense(
                bp["mixer"], h, st["k"], st["v"], lengths, cfg, window=window)
            new_st["k"], new_st["v"] = ck, cv
        else:
            y, kp, vp = A.attn_decode_paged(
                bp["mixer"], h, st["kp"], st["vp"], table, lengths, cfg,
                window=window, mode=kv_mode)
            new_st["kp"], new_st["vp"] = kp, vp
    elif mixer_kind == C.ATTN_MLA:
        y, ckv, kr = A.mla_decode(bp["mixer"], h, st["ckv"], st["kr"],
                                  lengths, cfg)
        new_st["ckv"], new_st["kr"] = ckv, kr
    elif mixer_kind == C.MAMBA:
        y, ms = M.mamba_decode(bp["mixer"], h,
                               {"conv": st["conv"], "ssm": st["ssm"]}, cfg)
        new_st.update(ms)
    elif mixer_kind == C.RWKV:
        y, rs = R.rwkv_decode(bp["mixer"], h,
                              {"wkv": st["wkv"], "shift": st["shift"]}, cfg)
        new_st.update(rs)
    else:
        raise ValueError(mixer_kind)
    x = x + y
    if enc_out is not None and "cross" in bp:
        hc = rmsnorm(bp["norm_cross"], x, cfg.rms_norm_eps)
        ek, ev = A.cross_kv(bp["cross"], enc_out, cfg)
        x = x + A.cross_attn_apply(bp["cross"], hc, ek, ev, cfg)
    h2 = rmsnorm(bp["norm2"], x, cfg.rms_norm_eps)
    shift_prev = st.get("ffn_shift")
    y2, aux = _apply_ffn(bp, h2, cfg, ffn_kind, shift_prev=shift_prev)
    if shift_prev is not None:
        new_st["ffn_shift"] = h2[:, 0].astype(jnp.float32)
    return x + y2, new_st, aux


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------
def stack_init(key, cfg, *, cross: bool = False) -> Params:
    """Params for prefix blocks + scanned periods."""
    kinds_prefix = list(cfg.prefix_pattern)
    pattern = list(cfg.layer_pattern)
    np_ = cfg.num_periods
    keys = jax.random.split(key, len(kinds_prefix) + np_ * len(pattern))
    prefix = [block_init(keys[i], cfg, mk, fk, cross)
              for i, (mk, fk) in enumerate(kinds_prefix)]
    base = len(kinds_prefix)

    def period_params(p):
        return {f"block_{j}": block_init(
            keys[base + p * len(pattern) + j], cfg, mk, fk, cross)
            for j, (mk, fk) in enumerate(pattern)}

    periods = [period_params(p) for p in range(np_)]
    scan = jax.tree.map(lambda *xs: jnp.stack(xs), *periods) if periods else {}
    return {"prefix": prefix, "scan": scan}


def stack_apply_train(params: Params, x, positions, cfg, *,
                      enc_out=None, causal: bool = True):
    """Returns (x, aux_sum). enc_out: encoder output for enc-dec stacks."""
    pattern = list(cfg.layer_pattern)
    aux = jnp.float32(0)
    for bp, (mk, fk) in zip(params["prefix"], cfg.prefix_pattern):
        x, a = block_apply_train(bp, x, positions, cfg, mk, fk, enc_out,
                                 causal)
        aux += a

    if cfg.num_periods == 0:
        return x, aux

    def period_body(carry, pp):
        x, aux = carry
        for j, (mk, fk) in enumerate(pattern):
            x, a = block_apply_train(pp[f"block_{j}"], x, positions, cfg,
                                     mk, fk, enc_out, causal)
            aux += a
        return (x, aux), None

    body = period_body
    if cfg.remat:
        body = jax.checkpoint(period_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["scan"])
    return x, aux


def stack_init_state(cfg, batch: int, max_len: int, kv_mode: str,
                     page_size: int, pages_per_layer: int):
    mk_state = lambda mk, fk: block_init_state(
        cfg, mk, fk, batch, max_len, kv_mode, page_size, pages_per_layer)
    prefix = [mk_state(mk, fk) for mk, fk in cfg.prefix_pattern]
    if cfg.num_periods == 0:
        return {"prefix": prefix, "scan": {}}
    period = {f"block_{j}": mk_state(mk, fk)
              for j, (mk, fk) in enumerate(cfg.layer_pattern)}
    scan = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape),
        period)
    return {"prefix": prefix, "scan": scan}


def stack_apply_decode(params: Params, state, x, lengths, cfg, *,
                       kv_mode: str, table=None, enc_out=None):
    """x: (B,1,D). Returns (x, new_state)."""
    pattern = list(cfg.layer_pattern)
    new_prefix = []
    for bp, st, (mk, fk) in zip(params["prefix"], state["prefix"],
                                cfg.prefix_pattern):
        x, nst, _ = block_apply_decode(bp, st, x, lengths, cfg, mk, fk,
                                       kv_mode, table, enc_out)
        new_prefix.append(nst)

    if cfg.num_periods == 0:
        return x, {"prefix": new_prefix, "scan": {}}

    def period_body(x, inp):
        pp, pst = inp
        new_pst = {}
        for j, (mk, fk) in enumerate(pattern):
            x, nst, _ = block_apply_decode(
                pp[f"block_{j}"], pst[f"block_{j}"], x, lengths, cfg,
                mk, fk, kv_mode, table, enc_out)
            new_pst[f"block_{j}"] = nst
        return x, new_pst

    x, new_scan = jax.lax.scan(period_body, x,
                               (params["scan"], state["scan"]))
    return x, {"prefix": new_prefix, "scan": new_scan}
