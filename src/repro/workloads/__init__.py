"""Workload axis of the simulator: Table-II synthetic generators plus
real-trace ingest, and the ONE parser every consumer resolves a
workload-axis value through (:func:`parse_workload_spec`)."""
import dataclasses
from typing import Dict

from repro.workloads.generators import (TRACE_PATTERNS,  # noqa: F401
                                        generate_trace, generate_traces,
                                        trace_cache_dir)
from repro.workloads.ingest import (TraceFormatError,  # noqa: F401
                                    ingest_trace, is_trace_spec,
                                    parse_trace_spec)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A parsed workload-axis value.

    ``kind`` is ``"named"`` (a Table-II generator; ``name`` indexes
    ``configs.ndp_sim.WORKLOADS``) or ``"trace"`` (``name`` is the
    trace file path, ``opts`` the validated ingest options).
    """

    kind: str
    name: str
    opts: Dict = dataclasses.field(default_factory=dict)

    def with_path(self, path: str) -> "WorkloadSpec":
        """Same spec, different trace path (path absolutization)."""
        assert self.kind == "trace", self
        return dataclasses.replace(self, name=path)

    def canonical(self) -> str:
        """Back to the string form (``"name"`` / ``"trace:<path>?..."``),
        options in parse order."""
        if self.kind == "named":
            return self.name
        query = "&".join(f"{k}={v}" for k, v in self.opts.items())
        return f"trace:{self.name}" + (f"?{query}" if query else "")


def parse_workload_spec(workload: str) -> WorkloadSpec:
    """Parse/validate a workload-axis value — the single authority every
    consumer (generators, sweep grids, search spaces, the simulator's
    trace resolution) goes through.

    ``"trace:<path>[?opt=val&...]"`` is a real-trace ingest spec;
    unknown or malformed query options raise ``ValueError`` loudly
    (:func:`repro.workloads.ingest.parse_trace_spec`).  Anything else
    must name a Table-II generator or it raises ``KeyError`` listing
    the known names.
    """
    if is_trace_spec(workload):
        path, opts = parse_trace_spec(workload)
        return WorkloadSpec("trace", path, opts)
    from repro.configs.ndp_sim import WORKLOADS
    if workload not in WORKLOADS:
        raise KeyError(f"unknown workload {workload!r}; known: "
                       f"{sorted(WORKLOADS)} (or a 'trace:<path>' spec)")
    return WorkloadSpec("named", str(workload))
