from repro.workloads.generators import (TRACE_PATTERNS,  # noqa: F401
                                        generate_trace, generate_traces,
                                        trace_cache_dir)
