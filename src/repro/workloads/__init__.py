from repro.workloads.generators import (TRACE_PATTERNS,  # noqa: F401
                                        generate_trace, generate_traces,
                                        trace_cache_dir)
from repro.workloads.ingest import (TraceFormatError,  # noqa: F401
                                    ingest_trace, is_trace_spec,
                                    parse_trace_spec)
