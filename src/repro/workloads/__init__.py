from repro.workloads.generators import generate_trace, TRACE_PATTERNS  # noqa: F401
