"""ChampSim binary trace parser.

ChampSim traces are a flat stream of fixed 64-byte records (the
``trace_instr_format_t`` of the ChampSim tracer: ip, branch flags,
2 destination + 4 source register ids, 2 destination + 4 source memory
addresses), usually xz- or gzip-compressed.  A zero memory slot means
"no access"; a record may carry up to six.

The parser is fully vectorized: records are ``np.frombuffer``-viewed
through a structured dtype block by block, memory slots are extracted
in record order (sources before destinations, matching the tracer's
operand order), and the ``work`` of each access — the number of
non-memory instructions retired since the previous memory access — is
derived from the gaps between memory-carrying records.  Only the first
access of a record carries its gap; same-record accesses are
back-to-back (work 0).

A trailing partial record raises :class:`TraceFormatError` — a
truncated download must fail loudly, not silently shorten the trace.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.workloads.ingest.io import TraceFormatError, open_stream

NUM_INSTR_DESTINATIONS = 2
NUM_INSTR_SOURCES = 4

RECORD_DTYPE = np.dtype([
    ("ip", "<u8"),
    ("is_branch", "u1"),
    ("branch_taken", "u1"),
    ("dst_reg", "u1", (NUM_INSTR_DESTINATIONS,)),
    ("src_reg", "u1", (NUM_INSTR_SOURCES,)),
    ("dst_mem", "<u8", (NUM_INSTR_DESTINATIONS,)),
    ("src_mem", "<u8", (NUM_INSTR_SOURCES,)),
])
RECORD_BYTES = RECORD_DTYPE.itemsize
assert RECORD_BYTES == 64

#: user-space mask: kernel/sign-extended addresses are folded positive
#: so the int64 view downstream never sees a negative address
_ADDR_MASK = np.uint64((1 << 63) - 1)

Block = Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]


def parse_blocks(path: str, block_records: int = 1 << 16
                 ) -> Iterator[Block]:
    """Yield ``(addr, work, tid)`` blocks; ``tid`` is always None
    (ChampSim traces are single-threaded — interleaving happens in the
    ingest pipeline)."""
    pending_work = 0
    offset = 0
    with open_stream(path) as f:
        while True:
            raw = f.read(RECORD_BYTES * block_records)
            if not raw:
                break
            # decompressors may return short reads mid-stream: top up to
            # a whole number of records before viewing
            need = (-len(raw)) % RECORD_BYTES
            while need:
                more = f.read(need)
                if not more:
                    raise TraceFormatError(
                        f"{path}: truncated ChampSim record at byte "
                        f"{offset + len(raw)} (stream is not a multiple "
                        f"of {RECORD_BYTES} bytes)")
                raw += more
                need = (-len(raw)) % RECORD_BYTES
            offset += len(raw)
            rec = np.frombuffer(raw, RECORD_DTYPE)

            mem = np.concatenate([rec["src_mem"], rec["dst_mem"]], axis=1)
            mask = mem != 0
            has_mem = mask.any(axis=1)
            pos = np.flatnonzero(has_mem)
            if pos.size == 0:
                pending_work += len(rec)
                continue
            # gap of silent (no-memory) records before each memory record
            prev = np.concatenate([[-1], pos[:-1]])
            gap = pos - prev - 1
            gap[0] += pending_work
            pending_work = int(len(rec) - 1 - pos[-1])

            rows, cols = np.nonzero(mask)      # row-major: record order
            addr = (mem[rows, cols] & _ADDR_MASK).astype(np.int64)
            work = np.zeros(rows.size, np.int64)
            work[np.searchsorted(rows, pos)] = gap
            yield addr, work, None
