"""Simple CSV / DynamoRIO-style text trace parser.

One memory access per line, comma- or whitespace-separated.  Two
layouts:

* **Headered** — the first non-comment line names the columns; known
  names (case-insensitive): ``addr``/``address``/``vaddr``,
  ``tid``/``thread``/``thread_id``, ``work``/``instrs``, and ``size``/
  ``op``/``type``/``pc`` (accepted but ignored).  ``addr`` is required.
* **Positional** — no header; columns are ``addr[,tid[,work]]``.

Addresses and integers parse as decimal, or hex with a ``0x`` prefix.
Lines starting with ``#`` and blank lines are skipped.  A row with the
wrong column count or an unparsable field raises
:class:`TraceFormatError` with its line number.

The ``tid`` column is what the ingest pipeline's ``interleave="thread"``
mode consumes — this is the one format that can carry real per-thread
streams (e.g. a DynamoRIO ``memtrace`` post-processed to csv).
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.workloads.ingest.io import TraceFormatError, open_stream

#: header-name -> canonical column (None: accepted, ignored)
_NAMES = {
    "addr": "addr", "address": "addr", "vaddr": "addr",
    "tid": "tid", "thread": "tid", "thread_id": "tid",
    "work": "work", "instrs": "work",
    "size": None, "op": None, "type": None, "pc": None,
}
_POSITIONAL = ("addr", "tid", "work")

Block = Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]


def _split(line: str) -> List[str]:
    if "," in line:
        return [t.strip() for t in line.split(",")]
    return line.split()


def _to_int(token: str, path: str, lineno: int) -> int:
    try:
        if token.lower().startswith("0x"):
            return int(token, 16)
        return int(token, 10)
    except ValueError:
        raise TraceFormatError(
            f"{path}:{lineno}: bad integer field {token!r}") from None


def parse_blocks(path: str, block_lines: int = 1 << 15) -> Iterator[Block]:
    """Yield ``(addr, work, tid)`` blocks; ``tid`` is None when the
    file has no thread column."""
    cols: Optional[List[str]] = None
    addrs: List[int] = []
    works: List[int] = []
    tids: List[int] = []
    have_tid = False

    def flush() -> Block:
        block = (np.asarray(addrs, np.int64),
                 np.asarray(works, np.int64),
                 np.asarray(tids, np.int64) if have_tid else None)
        addrs.clear(), works.clear(), tids.clear()
        return block

    with open_stream(path, text=True) as f:
        for lineno, line in enumerate(f, 1):
            s = line.strip()
            if not s or s.startswith("#"):
                continue
            tokens = _split(s)
            if cols is None:                    # first data line: sniff
                lowered = [t.lower() for t in tokens]
                if any(t in _NAMES for t in lowered):
                    cols = []
                    for t in lowered:
                        if t not in _NAMES:
                            raise TraceFormatError(
                                f"{path}:{lineno}: unknown column "
                                f"{t!r} (known: {sorted(_NAMES)})")
                        cols.append(_NAMES[t] or "_")
                    if "addr" not in cols:
                        raise TraceFormatError(
                            f"{path}:{lineno}: header has no addr column")
                    have_tid = "tid" in cols
                    continue                    # header consumed
                cols = list(_POSITIONAL[:len(tokens)])
                if not cols or len(tokens) > len(_POSITIONAL):
                    raise TraceFormatError(
                        f"{path}:{lineno}: expected 1-3 positional "
                        f"columns (addr[,tid[,work]]), got {len(tokens)}")
                have_tid = "tid" in cols
                # fall through: this line is data
            if len(tokens) != len(cols):
                raise TraceFormatError(
                    f"{path}:{lineno}: expected {len(cols)} fields, "
                    f"got {len(tokens)}")
            row = {c: _to_int(t, path, lineno)
                   for c, t in zip(cols, tokens) if c != "_"}
            addrs.append(row["addr"])
            works.append(row.get("work", 0))
            if have_tid:
                tids.append(row["tid"])
            if len(addrs) >= block_lines:
                yield flush()
    if addrs:
        yield flush()
