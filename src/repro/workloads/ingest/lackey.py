"""Valgrind lackey (``--trace-mem=yes``) text trace parser.

Lackey emits one line per event::

    I  04000047,3        instruction fetch (column 0!)
     L 04e2b848,8        data load
     S 04e2b850,4        data store
     M 0421dcd0,4        modify (load+store to one address)

``I`` lines count as non-memory work for the following access; ``L``,
``S`` and ``M`` each contribute one memory access at their (hex, no
``0x`` prefix) address.  Valgrind banner lines (``==pid==``) and blank
lines are skipped.  Anything else raises :class:`TraceFormatError`
with the offending line number — a corrupt or mis-identified file must
not silently parse as an empty trace.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.workloads.ingest.io import TraceFormatError, open_stream

Block = Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]


def parse_blocks(path: str, block_lines: int = 1 << 15) -> Iterator[Block]:
    """Yield ``(addr, work, tid)`` blocks; ``tid`` is always None
    (lackey interleaves threads into one stream)."""
    addrs, works = [], []
    work = 0
    with open_stream(path, text=True) as f:
        for lineno, line in enumerate(f, 1):
            s = line.strip()
            if not s or s.startswith("=="):
                continue
            if line.startswith("I"):           # instruction fetch
                work += 1
                continue
            kind, _, body = s.partition(" ")
            if kind not in ("L", "S", "M") or not body:
                raise TraceFormatError(
                    f"{path}:{lineno}: unrecognized lackey line "
                    f"{line.rstrip()!r}")
            token = body.strip().split(",", 1)[0]
            try:
                addr = int(token, 16)
            except ValueError:
                raise TraceFormatError(
                    f"{path}:{lineno}: bad lackey address "
                    f"{token!r}") from None
            addrs.append(addr)
            works.append(work)
            work = 0
            if len(addrs) >= block_lines:
                yield (np.asarray(addrs, np.int64),
                       np.asarray(works, np.int64), None)
                addrs, works = [], []
    if addrs:
        yield (np.asarray(addrs, np.int64),
               np.asarray(works, np.int64), None)
