"""Real-trace ingestion: ChampSim / Valgrind lackey / CSV -> simulator
traces.

The synthetic Table-II generators model the paper's workloads
statistically; this package replays the real thing.  Any supported
trace format streams into the exact ``{"vpn", "off", "work", "pages"}``
dict :func:`repro.sim.simulate` and the batch/sweep engines consume, so
real traces flow through every existing engine path with zero simulator
changes.  The dispatch point is :func:`repro.workloads.generate_trace`:
a workload name of the form ``"trace:<path>[?opt=val&...]"`` routes
here instead of the generators, which is what makes
``sweep({"workload": ("rnd", "trace:gups.champsim.xz")})`` or a
``simulate_batch`` lane over a real trace just work.

Pipeline
--------
1. **Parse** — the format parser (``champsim`` fixed 64-byte binary
   records, ``lackey`` text, ``csv`` text; auto-detected from the file
   name, ``.xz``/``.gz`` decompressed transparently) streams blocks of
   ``(addr, work[, tid])``: byte addresses plus the non-memory
   instruction count preceding each access.
2. **Interleave** — the single stream is split into ``num_cores``
   per-core streams: ``round_robin`` (access i -> core i mod C, the
   default — preserves per-core temporal structure of a multiprogrammed
   replay), ``blocked`` (contiguous C-way split), or ``thread`` (a csv
   ``tid`` column maps threads onto cores).  ``length`` clamps every
   core's stream (parsing stops early once enough accesses are read,
   except ``thread`` mode which must see the whole file).
3. **Page split + remap** — addresses split into ``(vpn, line-offset)``
   at a configurable ``page_bytes`` (default 4KB, the simulator's
   native page).  Sparse 64-bit vpns are compacted by a gap-capped
   monotone remap: page ordering and intra-region adjacency (deltas up
   to ``gap_cap`` pages, default one 2MB region) are preserved exactly
   — so leaf-PTE-line sharing, huge-page regions, and upper-level
   walk-line locality survive — while address-space gaps collapse to
   ``gap_cap``, keeping vpns int32-safe for the engine.
4. **Cache** — results memoize through the same ``.trace_cache`` npz
   layer as the generators, keyed by (file sha256, parser, every
   pipeline option, ingest version): touching the trace file or any
   option can never serve a stale cached trace.

``scripts/convert_trace.py`` is the CLI over this module;
``benchmarks/trace_validate.py`` replays real traces against their
matched synthetic generators.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import numpy as np

from repro.workloads.ingest import champsim, lackey, textcsv
from repro.workloads.ingest.io import (TraceFormatError,  # noqa: F401
                                       file_sha256, open_stream)

#: bump on any behavior change so stale .trace_cache entries are never
#: served (the CI cache step is additionally keyed on this package's
#: file hashes)
_INGEST_VERSION = 1

PARSERS = {
    "champsim": champsim.parse_blocks,
    "lackey": lackey.parse_blocks,
    "csv": textcsv.parse_blocks,
}

INTERLEAVES = ("round_robin", "blocked", "thread")

#: one 2MB huge-page region, in 4KB pages — the default gap cap keeps
#: distinct allocation regions in distinct huge regions after remap
DEFAULT_GAP_CAP = 512
DEFAULT_WORK_CLIP = 64


def detect_format(path: str) -> str:
    """Infer the parser from the file name (compression suffixes are
    ignored): ``*.champsim*``/``*.trace*`` -> champsim, ``*lackey*`` ->
    lackey, ``*.csv``/``*.txt``/``*.mem`` -> csv."""
    name = os.path.basename(path).lower()
    for suffix in (".xz", ".gz"):
        if name.endswith(suffix):
            name = name[: -len(suffix)]
    if ".champsim" in name or name.endswith(".trace"):
        return "champsim"
    if "lackey" in name:
        return "lackey"
    if name.endswith((".csv", ".txt", ".mem")):
        return "csv"
    raise TraceFormatError(
        f"cannot infer trace format from {path!r}; pass fmt= "
        f"(one of {sorted(PARSERS)})")


# ---------------------------------------------------------------------------
# trace:<path>?opt=val workload specs
# ---------------------------------------------------------------------------
_SPEC_PREFIX = "trace:"
_SPEC_OPTS = {"fmt": str, "interleave": str, "page_bytes": int,
              "work_clip": int, "gap_cap": int}


def is_trace_spec(workload) -> bool:
    """True for ``"trace:<path>"`` workload-axis values."""
    return isinstance(workload, str) and workload.startswith(_SPEC_PREFIX)


def parse_trace_spec(spec: str) -> Tuple[str, Dict]:
    """``"trace:<path>[?opt=val&opt=val]"`` -> (path, option dict).

    Options mirror :func:`ingest_trace` keywords: ``fmt``,
    ``interleave``, ``page_bytes``, ``work_clip``, ``gap_cap``.
    """
    if not is_trace_spec(spec):
        raise ValueError(f"not a trace spec: {spec!r}")
    rest = spec[len(_SPEC_PREFIX):]
    path, _, query = rest.partition("?")
    if not path:
        raise ValueError(f"trace spec {spec!r} has an empty path")
    opts: Dict = {}
    if query:
        for item in query.split("&"):
            key, sep, value = item.partition("=")
            if not sep or key not in _SPEC_OPTS:
                raise ValueError(
                    f"trace spec {spec!r}: bad option {item!r} "
                    f"(known: {sorted(_SPEC_OPTS)})")
            opts[key] = _SPEC_OPTS[key](value)
    return path, opts


# ---------------------------------------------------------------------------
# pipeline stages
# ---------------------------------------------------------------------------
def _interleave(addr: np.ndarray, work: np.ndarray,
                tid: Optional[np.ndarray], num_cores: int, mode: str,
                path: str) -> Tuple[np.ndarray, np.ndarray]:
    """One stream -> (num_cores, n) per-core addr/work arrays."""
    total = addr.size
    if mode == "round_robin":
        n = total // num_cores
        if n == 0:
            raise TraceFormatError(
                f"{path}: only {total} accesses — too short for "
                f"{num_cores} cores")
        a = addr[: n * num_cores].reshape(n, num_cores).T
        w = work[: n * num_cores].reshape(n, num_cores).T
        return a, w
    if mode == "blocked":
        n = total // num_cores
        if n == 0:
            raise TraceFormatError(
                f"{path}: only {total} accesses — too short for "
                f"{num_cores} cores")
        return (addr[: n * num_cores].reshape(num_cores, n),
                work[: n * num_cores].reshape(num_cores, n))
    if mode == "thread":
        if tid is None:
            raise TraceFormatError(
                f"{path}: interleave='thread' needs a tid column "
                "(csv format only)")
        uniq, first = np.unique(tid, return_index=True)
        order = uniq[np.argsort(first)]        # thread appearance order
        streams = []
        for c in range(num_cores):
            mask = np.isin(tid, order[c::num_cores])
            streams.append((addr[mask], work[mask]))
        n = min(s[0].size for s in streams)
        if n == 0:
            raise TraceFormatError(
                f"{path}: {order.size} threads cannot fill "
                f"{num_cores} cores")
        return (np.stack([s[0][:n] for s in streams]),
                np.stack([s[1][:n] for s in streams]))
    raise ValueError(f"unknown interleave {mode!r}; "
                     f"known: {INTERLEAVES}")


def _compact_vpns(vpn64: np.ndarray, gap_cap: int,
                  path: str) -> Tuple[np.ndarray, int]:
    """Gap-capped monotone vpn remap (see module docstring)."""
    flat = vpn64.ravel()
    uniq = np.unique(flat)
    new = np.zeros(uniq.size, np.int64)
    if uniq.size > 1:
        np.cumsum(np.minimum(np.diff(uniq), gap_cap), out=new[1:])
    pages = int(new[-1]) + 1
    if pages >= 1 << 31:
        raise TraceFormatError(
            f"{path}: {pages} pages after remap overflow int32 — "
            f"lower gap_cap (now {gap_cap})")
    remapped = new[np.searchsorted(uniq, flat)].reshape(vpn64.shape)
    return remapped, pages


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def ingest_trace(path: str, num_cores: int, *,
                 length: Optional[int] = None,
                 fmt: Optional[str] = None,
                 interleave: str = "round_robin",
                 page_bytes: int = 4096,
                 work_clip: int = DEFAULT_WORK_CLIP,
                 gap_cap: int = DEFAULT_GAP_CAP,
                 use_cache: bool = True) -> Dict[str, np.ndarray]:
    """Parse a real memory trace into a simulator trace dict.

    Returns ``{"vpn", "off", "work"}`` int32 arrays of shape
    ``(num_cores, n)`` plus the remapped footprint ``"pages"`` — the
    same contract as :func:`repro.workloads.generate_trace`.

    ``length`` clamps each core's stream (``n <= length``); a shorter
    file yields fewer accesses, which the engines handle via their
    per-lane valid masks.  ``page_bytes`` (power of two, >= 128) sets
    the vpn/offset split — the simulator's timing model natively
    assumes 4KB pages; other sizes are for trace analysis via
    :mod:`scripts.convert_trace`.  ``work_clip`` bounds per-access
    work so one huge compute gap cannot dominate the window.
    ``use_cache=False`` bypasses the on-disk ``.trace_cache`` layer.
    """
    if num_cores < 1:
        raise ValueError(f"num_cores must be >= 1, got {num_cores}")
    if page_bytes < 128 or page_bytes & (page_bytes - 1):
        raise ValueError(
            f"page_bytes must be a power of two >= 128, got {page_bytes}")
    if gap_cap < 1:
        raise ValueError(f"gap_cap must be >= 1, got {gap_cap} "
                         "(0 would collapse every page to vpn 0)")
    if work_clip < 0:
        raise ValueError(f"work_clip must be >= 0, got {work_clip}")
    if interleave not in INTERLEAVES:
        raise ValueError(f"unknown interleave {interleave!r}; "
                         f"known: {INTERLEAVES}")
    fmt = fmt or detect_format(path)
    if fmt not in PARSERS:
        raise TraceFormatError(f"unknown trace format {fmt!r}; "
                               f"known: {sorted(PARSERS)}")

    from repro.workloads import generators as G
    cache_path = None
    if use_cache and G.trace_cache_dir() is not None:
        key = (f"ingest_{file_sha256(path)[:20]}_{fmt}_c{num_cores}"
               f"_n{length}_i{interleave}_p{page_bytes}_w{work_clip}"
               f"_g{gap_cap}_v{_INGEST_VERSION}")
        cache_path = os.path.join(G.trace_cache_dir(), key + ".npz")
        cached = G._cache_load(cache_path)
        if cached is not None:
            return cached

    # stream the parser; stop early once the clamp window is full
    # (thread mode must see the whole file — tids interleave arbitrarily)
    cap = (length * num_cores
           if length is not None and interleave != "thread" else None)
    addr_bl, work_bl, tid_bl = [], [], []
    total = 0
    tid_seen = None
    for addr, work, tid in PARSERS[fmt](path):
        addr_bl.append(addr)
        work_bl.append(work)
        if tid_seen is None:
            tid_seen = tid is not None
        if tid_seen:
            tid_bl.append(tid)
        total += addr.size
        if cap is not None and total >= cap:
            break
    if total == 0:
        raise TraceFormatError(f"{path}: trace contains no memory "
                               f"accesses (format {fmt!r})")
    addr = np.concatenate(addr_bl)
    work = np.clip(np.concatenate(work_bl), 0, work_clip)
    tid = np.concatenate(tid_bl) if tid_bl else None
    if cap is not None:
        addr, work = addr[:cap], work[:cap]

    a, w = _interleave(addr, work, tid, num_cores, interleave, path)
    if length is not None:
        a, w = a[:, :length], w[:, :length]

    shift = page_bytes.bit_length() - 1
    vpn, pages = _compact_vpns(a >> shift, gap_cap, path)
    off = (a & (page_bytes - 1)) >> 6
    trace = {"vpn": vpn.astype(np.int32), "off": off.astype(np.int32),
             "work": w.astype(np.int32), "pages": pages}
    G._cache_store(cache_path, trace)
    return trace
