"""Shared I/O helpers for the trace parsers: compression-aware open
(``.xz``/``.gz``/plain, all stdlib) and the streaming file digest the
ingest cache keys on."""
from __future__ import annotations

import gzip
import hashlib
import io
import lzma


class TraceFormatError(ValueError):
    """A trace file that cannot be parsed: wrong/undetectable format,
    truncated binary record, malformed text line, or a stream with no
    memory accesses at all."""


def open_stream(path: str, text: bool = False):
    """Open ``path`` for reading, transparently decompressing by suffix
    (``.xz`` -> lzma, ``.gz`` -> gzip, else plain).  ``text=True`` wraps
    the byte stream for line iteration."""
    if path.endswith(".xz"):
        f = lzma.open(path, "rb")
    elif path.endswith(".gz"):
        f = gzip.open(path, "rb")
    else:
        f = open(path, "rb")
    if text:
        return io.TextIOWrapper(f, encoding="utf-8", errors="replace")
    return f


def file_sha256(path: str, block: int = 1 << 20) -> str:
    """Streaming sha256 of the file AS STORED (compressed bytes): the
    cache key must change when the file does, nothing more."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(block)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()
