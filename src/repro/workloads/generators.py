"""Synthetic memory-trace generators for the Table-II workloads.

Each generator emits, per core, a stream of (vpn, line_offset, work) where
``vpn`` is the 4KB virtual page, ``line_offset`` the 64B line within it and
``work`` the non-memory instructions preceding the access.  The statistical
structure (footprint, reuse, spatial locality, burstiness) is modelled on
the published characterizations of the suites:

  GUPS (rnd)        uniform random updates over the whole table
  GraphBIG (bc,cc,  power-law vertex access (zipf-ish) mixed with short
   gc,tc)           sequential runs over CSR arrays
  bfs / sp          frontier bursts: sequential frontier scan + random
                    neighbour expansion
  pr (sweep)        sequential property sweep + random edge endpoints
  XSBench (xs)      random nuclide/grid lookups with binary-search ladders
  DLRM (dlrm)       embedding-bag: bursts of ~40 random rows (mild zipf)
                    + a dense sequential MLP segment
  GenomicsBench     k-mer hash probes: uniform probes + 2-line runs
   (gen)

Footprints follow Table II UNSCALED (full dataset sizes): the simulated
windows are shorter than 500M instructions, but all the structural ratios
that drive the paper's effects (footprint >> TLB reach, PT working set >>
L1, PL1/PL2 full occupancy) are preserved exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

FOOTPRINT_SCALE = 1.0
PAGE_LINES = 64  # 4KB / 64B


def _pages(footprint_gb: float) -> int:
    return max(1 << 14, int(footprint_gb * FOOTPRINT_SCALE * (1 << 18)))


def _powerlaw(rng, n: int, pages: int, alpha: float) -> np.ndarray:
    """Zipf-flavoured page ids in [0, pages): small ids are hot."""
    u = rng.random(n)
    x = np.floor(pages * u ** alpha).astype(np.int64)
    return np.minimum(x, pages - 1)


def _hot_lines(rng, n: int, pages: int, alpha: float) -> np.ndarray:
    """Power-law LINE accesses: hot vertices reuse their exact lines, and
    hot ids are CONTIGUOUS (degree-renumbered vertex arrays) — so hot pages
    and their leaf PTEs exhibit the cacheable locality real graph codes
    show on CPU-class cache hierarchies."""
    total = pages * PAGE_LINES
    u = rng.random(n)
    x = np.floor(total * u ** alpha).astype(np.int64)
    return np.minimum(x, total - 1)


def _runs(rng, n: int, pages: int, run_len: int, rep: int = 6) -> np.ndarray:
    """Sequential runs: each 64B line is touched ``rep`` times in a row
    (word-granular streaming over arrays) for ~run_len distinct lines."""
    n_lines = max(1, n // (run_len * rep)) * run_len
    starts = rng.integers(0, pages, max(1, n_lines // run_len)) * PAGE_LINES
    offs = np.arange(run_len)
    lines = (starts[:, None] + offs[None, :]).reshape(-1)
    lines = np.repeat(lines, rep)[:n]
    if len(lines) < n:
        lines = np.pad(lines, (0, n - len(lines)), mode="wrap")
    return lines % (pages * PAGE_LINES)


def _mix_streams(rng, parts, weights, n):
    """Interleave line-granular streams according to weights, consuming
    each stream IN ORDER (preserves runs / repetition structure)."""
    choice = rng.choice(len(parts), size=n, p=np.asarray(weights) /
                        np.sum(weights))
    out = np.empty(n, np.int64)
    for i, p in enumerate(parts):
        idx = np.where(choice == i)[0]
        take = np.arange(len(idx)) % len(p)
        out[idx] = p[take]
    return out


def _emit(lines: np.ndarray, work: np.ndarray):
    vpn = (lines // PAGE_LINES).astype(np.int32)
    off = (lines % PAGE_LINES).astype(np.int32)
    return vpn, off, work.astype(np.int32)


def gen_uniform(rng, n, pages):
    lines = rng.integers(0, pages * PAGE_LINES, n)
    work = rng.integers(1, 4, n)
    return _emit(lines, work)


def gen_graph(rng, n, pages, alpha=2.1):
    hot = _hot_lines(rng, n, pages, 2 * alpha)             # hot vertices
    seq = _runs(rng, n, pages, run_len=8, rep=8)           # CSR scans
    cold = rng.integers(0, pages * PAGE_LINES, n)          # cold neighbours
    lines = _mix_streams(rng, [hot, seq, cold], [0.5, 0.35, 0.15], n)
    work = rng.integers(2, 7, n)
    return _emit(lines, work)


def gen_graph_frontier(rng, n, pages, alpha=2.1):
    frontier = _runs(rng, n, pages, run_len=32, rep=8)     # frontier scan
    expand = _hot_lines(rng, n, pages, 2 * alpha)          # hot neighbours
    cold = rng.integers(0, pages * PAGE_LINES, n)
    lines = _mix_streams(rng, [frontier, expand, cold], [0.45, 0.35, 0.2], n)
    work = rng.integers(2, 6, n)
    return _emit(lines, work)


def gen_graph_sweep(rng, n, pages, alpha=2.1):
    sweep = np.repeat(np.arange(n // 8 + 1), 8)[:n] % (
        pages * PAGE_LINES)                                # property sweep
    edges = rng.integers(0, pages * PAGE_LINES, n)         # edge endpoints
    hot = _hot_lines(rng, n, pages, 2 * alpha)             # hot vertices
    lines = _mix_streams(rng, [sweep, edges, hot], [0.5, 0.25, 0.25], n)
    work = rng.integers(2, 5, n)
    return _emit(lines, work)


def gen_mc_lookup(rng, n, pages):
    """XSBench: random energy -> binary-search ladder over grid pages, then
    a short sequential read of the nuclide data (few lines, word-granular)."""
    ladder = 6
    read = 6
    n_look = max(1, n // (ladder + read))
    centers = rng.integers(0, pages, n_look)
    cols = []
    for step in range(ladder):
        stride = max(pages >> (step + 1), 1)
        if step < 3:
            # top of the search tree: the same few nodes on every lookup
            node = (pages >> 1) // max(stride, 1) * stride % pages
            jitter = np.full(n_look, node)
        else:
            jitter = ((centers + (rng.integers(0, 2, n_look) * 2 - 1)
                       * stride) % pages)
        cols.append(jitter * PAGE_LINES + (_hash32(jitter) % PAGE_LINES))
    hit_line = centers * PAGE_LINES + rng.integers(0, PAGE_LINES, n_look)
    for r in range(read):
        cols.append(hit_line + (r // 3))         # ~2 lines, reused
    lines = np.stack(cols, axis=1).reshape(-1)[:n]
    if len(lines) < n:
        lines = np.pad(lines, (0, n - len(lines)), mode="wrap")
    work = rng.integers(4, 9, n)
    return _emit(lines, work)


def gen_embedding_bag(rng, n, pages):
    """DLRM sparse-length-sum: bags of random rows (each row ~2 lines read
    word-by-word) + a dense sequential MLP segment."""
    rows = _hot_lines(rng, n, pages, alpha=2.2)
    rows = np.repeat(rows[: max(1, n // 4)], 4)[:n]        # row = 4 touches
    dense = _runs(rng, n, max(pages // 64, 1), run_len=64, rep=8)
    lines = _mix_streams(rng, [rows, dense], [0.6, 0.4], n)
    work = rng.integers(1, 4, n)
    return _emit(lines, work)


def gen_kmer(rng, n, pages):
    probes = rng.integers(0, pages * PAGE_LINES, n)
    probes = np.repeat(probes[: max(1, n // 3)], 3)[:n]    # probe+payload
    runs = _runs(rng, n, pages, run_len=4, rep=8)
    lines = _mix_streams(rng, [probes, runs], [0.55, 0.45], n)
    work = rng.integers(2, 6, n)
    return _emit(lines, work)


def _hash32(x):
    x = np.asarray(x, np.uint32) ^ np.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    return (x ^ (x >> 15)).astype(np.int64)


TRACE_PATTERNS = {
    "uniform": gen_uniform,
    "graph": gen_graph,
    "graph_frontier": gen_graph_frontier,
    "graph_sweep": gen_graph_sweep,
    "mc_lookup": gen_mc_lookup,
    "embedding_bag": gen_embedding_bag,
    "kmer": gen_kmer,
}


def generate_trace(workload: str, num_cores: int, length: int | None = None,
                   seed: int | None = None,
                   preset=None) -> Dict[str, np.ndarray]:
    """Per-core traces for a Table-II workload.

    Returns dict with vpn/off/work arrays of shape (num_cores, length).
    All cores share the dataset (same footprint region, different seeds).

    ``preset`` is a :class:`repro.configs.ndp_sim.SimPreset` (or its name,
    e.g. ``"smoke"``) supplying defaults for ``length`` and ``seed`` and
    scaling the Table-II footprint; explicit ``length``/``seed`` win.
    """
    from repro.configs.ndp_sim import PRESETS, WORKLOADS
    scale = 1.0
    if preset is not None:
        if isinstance(preset, str):
            preset = PRESETS[preset]
        length = preset.trace_len if length is None else length
        seed = preset.seed if seed is None else seed
        scale = preset.footprint_scale
    if length is None:
        raise TypeError("generate_trace needs `length` or a `preset`")
    if seed is None:
        seed = 0
    spec = WORKLOADS[workload]
    pattern = TRACE_PATTERNS[spec["pattern"]]
    pages = _pages(spec["footprint_gb"] * scale)
    vpns, offs, works = [], [], []
    for c in range(num_cores):
        rng = np.random.default_rng(seed * 1009 + c * 101 + hash(workload)
                                    % 65536)
        kwargs = {}
        if "alpha" in spec and "alpha" in pattern.__code__.co_varnames:
            kwargs["alpha"] = spec["alpha"]
        v, o, w = pattern(rng, length, pages, **kwargs)
        vpns.append(v)
        offs.append(o)
        works.append(w)
    return {
        "vpn": np.stack(vpns),
        "off": np.stack(offs),
        "work": np.stack(works),
        "pages": pages,
    }
