"""Synthetic memory-trace generators for the Table-II workloads.

Each generator emits, for ALL cores at once, a stream of (vpn, line_offset,
work) where ``vpn`` is the 4KB virtual page, ``line_offset`` the 64B line
within it and ``work`` the non-memory instructions preceding the access.
The statistical structure (footprint, reuse, spatial locality, burstiness)
is modelled on the published characterizations of the suites:

  GUPS (rnd)        uniform random updates over the whole table
  GraphBIG (bc,cc,  power-law vertex access (zipf-ish) mixed with short
   gc,tc)           sequential runs over CSR arrays
  bfs / sp          frontier bursts: sequential frontier scan + random
                    neighbour expansion
  pr (sweep)        sequential property sweep + random edge endpoints
  XSBench (xs)      random nuclide/grid lookups with binary-search ladders
  DLRM (dlrm)       embedding-bag: bursts of ~40 random rows (mild zipf)
                    + a dense sequential MLP segment
  GenomicsBench     k-mer hash probes: uniform probes + 2-line runs
   (gen)

Footprints follow Table II UNSCALED (full dataset sizes): the simulated
windows are shorter than 500M instructions, but all the structural ratios
that drive the paper's effects (footprint >> TLB reach, PT working set >>
L1, PL1/PL2 full occupancy) are preserved exactly.

Generation is fully vectorized over the core axis — every generator
produces ``(num_cores, length)`` arrays from one ``numpy`` RNG seeded with
a *stable* hash of the workload name (``zlib.crc32``; Python's ``hash()``
is randomized per process), so traces are bit-identical across processes
without pinning ``PYTHONHASHSEED``.  Generated traces are memoized to an
on-disk cache (``.trace_cache/`` at the repo root; override the directory
with ``SIM_TRACE_CACHE=<dir>``, disable with ``SIM_TRACE_CACHE=0``) so
repeated benchmark/test runs skip generation entirely.  ``rm -rf
.trace_cache`` clears it; ``_CACHE_VERSION`` below invalidates it whenever
the generators change.
"""
from __future__ import annotations

import os
import zlib
from typing import Dict, List, Sequence

import numpy as np

from repro.util import resilience

FOOTPRINT_SCALE = 1.0
PAGE_LINES = 64  # 4KB / 64B

#: bump on any change to the generators so stale .trace_cache entries are
#: never served
_CACHE_VERSION = 2


def _pages(footprint_gb: float) -> int:
    return max(1 << 14, int(footprint_gb * FOOTPRINT_SCALE * (1 << 18)))


def _stable_hash(s: str) -> int:
    """Process-stable workload hash (crc32), unlike builtin ``hash``."""
    return zlib.crc32(s.encode("utf-8"))


def _hot_lines(rng, shape, pages: int, alpha: float) -> np.ndarray:
    """Power-law LINE accesses: hot vertices reuse their exact lines, and
    hot ids are CONTIGUOUS (degree-renumbered vertex arrays) — so hot pages
    and their leaf PTEs exhibit the cacheable locality real graph codes
    show on CPU-class cache hierarchies."""
    total = pages * PAGE_LINES
    u = rng.random(shape)
    x = np.floor(total * u ** alpha).astype(np.int64)
    return np.minimum(x, total - 1)


def _runs(rng, cores: int, n: int, pages: int, run_len: int,
          rep: int = 6) -> np.ndarray:
    """Sequential runs: each 64B line is touched ``rep`` times in a row
    (word-granular streaming over arrays) for ~run_len distinct lines."""
    n_lines = max(1, n // (run_len * rep)) * run_len
    starts = rng.integers(0, pages,
                          (cores, max(1, n_lines // run_len))) * PAGE_LINES
    offs = np.arange(run_len)
    lines = (starts[..., None] + offs[None, None, :]).reshape(cores, -1)
    lines = np.repeat(lines, rep, axis=1)[:, :n]
    if lines.shape[1] < n:
        lines = np.pad(lines, ((0, 0), (0, n - lines.shape[1])), mode="wrap")
    return lines % (pages * PAGE_LINES)


def _mix_streams(rng, parts, weights, n: int) -> np.ndarray:
    """Interleave line-granular streams according to weights, consuming
    each stream IN ORDER per core (preserves runs / repetition structure)."""
    cores = parts[0].shape[0]
    choice = rng.choice(len(parts), size=(cores, n),
                        p=np.asarray(weights) / np.sum(weights))
    out = np.empty((cores, n), np.int64)
    for i, p in enumerate(parts):
        mask = choice == i
        # position within stream i = running count of stream-i picks
        take = (np.cumsum(mask, axis=1) - 1) % p.shape[1]
        vals = np.take_along_axis(np.ascontiguousarray(p), take, axis=1)
        out[mask] = vals[mask]
    return out


def _emit(lines: np.ndarray, work: np.ndarray):
    vpn = (lines // PAGE_LINES).astype(np.int32)
    off = (lines % PAGE_LINES).astype(np.int32)
    return vpn, off, work.astype(np.int32)


def gen_uniform(rng, cores, n, pages):
    lines = rng.integers(0, pages * PAGE_LINES, (cores, n))
    work = rng.integers(1, 4, (cores, n))
    return _emit(lines, work)


def gen_graph(rng, cores, n, pages, alpha=2.1):
    hot = _hot_lines(rng, (cores, n), pages, 2 * alpha)    # hot vertices
    seq = _runs(rng, cores, n, pages, run_len=8, rep=8)    # CSR scans
    cold = rng.integers(0, pages * PAGE_LINES, (cores, n))  # cold neighbours
    lines = _mix_streams(rng, [hot, seq, cold], [0.5, 0.35, 0.15], n)
    work = rng.integers(2, 7, (cores, n))
    return _emit(lines, work)


def gen_graph_frontier(rng, cores, n, pages, alpha=2.1):
    frontier = _runs(rng, cores, n, pages, run_len=32, rep=8)
    expand = _hot_lines(rng, (cores, n), pages, 2 * alpha)  # hot neighbours
    cold = rng.integers(0, pages * PAGE_LINES, (cores, n))
    lines = _mix_streams(rng, [frontier, expand, cold], [0.45, 0.35, 0.2], n)
    work = rng.integers(2, 6, (cores, n))
    return _emit(lines, work)


def gen_graph_sweep(rng, cores, n, pages, alpha=2.1):
    sweep = np.broadcast_to(                               # property sweep
        np.repeat(np.arange(n // 8 + 1), 8)[:n] % (pages * PAGE_LINES),
        (cores, n))
    edges = rng.integers(0, pages * PAGE_LINES, (cores, n))  # edge endpoints
    hot = _hot_lines(rng, (cores, n), pages, 2 * alpha)    # hot vertices
    lines = _mix_streams(rng, [sweep, edges, hot], [0.5, 0.25, 0.25], n)
    work = rng.integers(2, 5, (cores, n))
    return _emit(lines, work)


def gen_mc_lookup(rng, cores, n, pages):
    """XSBench: random energy -> binary-search ladder over grid pages, then
    a short sequential read of the nuclide data (few lines, word-granular)."""
    ladder = 6
    read = 6
    n_look = max(1, n // (ladder + read))
    centers = rng.integers(0, pages, (cores, n_look))
    cols = []
    for step in range(ladder):
        stride = max(pages >> (step + 1), 1)
        if step < 3:
            # top of the search tree: the same few nodes on every lookup
            node = (pages >> 1) // max(stride, 1) * stride % pages
            jitter = np.full((cores, n_look), node)
        else:
            jitter = ((centers + (rng.integers(0, 2, (cores, n_look)) * 2 - 1)
                       * stride) % pages)
        cols.append(jitter * PAGE_LINES + (_hash32(jitter) % PAGE_LINES))
    hit_line = centers * PAGE_LINES + rng.integers(0, PAGE_LINES,
                                                   (cores, n_look))
    for r in range(read):
        cols.append(hit_line + (r // 3))         # ~2 lines, reused
    lines = np.stack(cols, axis=2).reshape(cores, -1)[:, :n]
    if lines.shape[1] < n:
        lines = np.pad(lines, ((0, 0), (0, n - lines.shape[1])), mode="wrap")
    work = rng.integers(4, 9, (cores, n))
    return _emit(lines, work)


def gen_embedding_bag(rng, cores, n, pages):
    """DLRM sparse-length-sum: bags of random rows (each row ~2 lines read
    word-by-word) + a dense sequential MLP segment."""
    rows = _hot_lines(rng, (cores, n), pages, alpha=2.2)
    rows = np.repeat(rows[:, : max(1, n // 4)], 4, axis=1)[:, :n]
    dense = _runs(rng, cores, n, max(pages // 64, 1), run_len=64, rep=8)
    lines = _mix_streams(rng, [rows, dense], [0.6, 0.4], n)
    work = rng.integers(1, 4, (cores, n))
    return _emit(lines, work)


def gen_kmer(rng, cores, n, pages):
    probes = rng.integers(0, pages * PAGE_LINES, (cores, n))
    probes = np.repeat(probes[:, : max(1, n // 3)], 3, axis=1)[:, :n]
    runs = _runs(rng, cores, n, pages, run_len=4, rep=8)
    lines = _mix_streams(rng, [probes, runs], [0.55, 0.45], n)
    work = rng.integers(2, 6, (cores, n))
    return _emit(lines, work)


def _hash32(x):
    x = np.asarray(x, np.uint32) ^ np.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    return (x ^ (x >> 15)).astype(np.int64)


TRACE_PATTERNS = {
    "uniform": gen_uniform,
    "graph": gen_graph,
    "graph_frontier": gen_graph_frontier,
    "graph_sweep": gen_graph_sweep,
    "mc_lookup": gen_mc_lookup,
    "embedding_bag": gen_embedding_bag,
    "kmer": gen_kmer,
}


# ---------------------------------------------------------------------------
# on-disk trace cache
# ---------------------------------------------------------------------------
def trace_cache_dir() -> str | None:
    """Resolved cache directory, or None when disabled (SIM_TRACE_CACHE=0)."""
    env = os.environ.get("SIM_TRACE_CACHE")
    if env == "0":
        return None
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, ".trace_cache")


def _cache_path(workload: str, cores: int, length: int, seed: int,
                spec: dict, pages: int) -> str | None:
    d = trace_cache_dir()
    if d is None:
        return None
    # the key covers everything the trace depends on: the resolved page
    # count (folds footprint_gb and every scale knob), the generator
    # pattern and its alpha — so editing a WORKLOADS entry in
    # configs/ndp_sim.py can never serve a stale cached trace
    key = (f"{workload}_c{cores}_n{length}_s{seed}_p{pages}"
           f"_g{spec['pattern']}_a{spec.get('alpha', 0):g}"
           f"_v{_CACHE_VERSION}")
    return os.path.join(d, key + ".npz")


def _cache_load(path: str | None) -> Dict[str, np.ndarray] | None:
    """Integrity-checked load: a truncated or bit-flipped entry (killed
    nightly writer, disk corruption) is QUARANTINED and None returned —
    the caller regenerates, exactly like the OSError degrade path."""
    if path is None:
        return None
    arrays = resilience.read_npz(path)
    if arrays is None:
        return None
    try:
        return {"vpn": arrays["vpn"], "off": arrays["off"],
                "work": arrays["work"], "pages": int(arrays["pages"])}
    except KeyError:                     # entry from an older schema
        resilience.quarantine(path, "missing trace arrays")
        return None


def _cache_store(path: str | None, trace: Dict[str, np.ndarray]) -> None:
    if path is None:
        return
    # the cache is an optimization: any filesystem failure (read-only
    # checkout, unwritable SIM_TRACE_CACHE) degrades to cache-off.
    # Writes are atomic (temp + rename) with a sha256 sidecar, so
    # concurrent writers never publish torn files and readers detect
    # corruption (repro.util.resilience owns both halves).
    resilience.write_npz(path, {"vpn": trace["vpn"], "off": trace["off"],
                                "work": trace["work"],
                                "pages": trace["pages"]})


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def generate_trace(workload: str, num_cores: int, length: int | None = None,
                   seed: int | None = None, preset=None,
                   use_cache: bool = True) -> Dict[str, np.ndarray]:
    """Per-core traces for a Table-II workload.

    Returns dict with vpn/off/work arrays of shape (num_cores, length).
    All cores share the dataset (same footprint region) and draw from one
    vectorized RNG, so no per-core Python loop runs.

    ``preset`` is a :class:`repro.configs.ndp_sim.SimPreset` (or its name,
    e.g. ``"smoke"``) supplying defaults for ``length`` and ``seed`` and
    scaling the Table-II footprint; explicit ``length``/``seed`` win.
    ``use_cache=False`` bypasses the on-disk trace cache for this call.

    A ``workload`` of the form ``"trace:<path>[?opt=val&...]"`` ingests
    a REAL trace (ChampSim / Valgrind lackey / csv — see
    :mod:`repro.workloads.ingest`) instead of generating a synthetic
    one: ``length`` clamps it (``None`` replays the whole file),
    ``seed`` and the footprint scale are meaningless and ignored.
    """
    from repro.configs.ndp_sim import PRESETS, WORKLOADS
    from repro.workloads import parse_workload_spec
    scale = 1.0
    if preset is not None:
        if isinstance(preset, str):
            preset = PRESETS[preset]
        length = preset.trace_len if length is None else length
        seed = preset.seed if seed is None else seed
        scale = preset.footprint_scale
    wspec = parse_workload_spec(workload)
    if wspec.kind == "trace":
        from repro.workloads.ingest import ingest_trace
        return ingest_trace(wspec.name, num_cores, length=length,
                            use_cache=use_cache, **wspec.opts)
    if length is None:
        raise TypeError("generate_trace needs `length` or a `preset`")
    if seed is None:
        seed = 0

    spec = WORKLOADS[workload]
    pattern = TRACE_PATTERNS[spec["pattern"]]
    pages = _pages(spec["footprint_gb"] * scale)

    path = _cache_path(workload, num_cores, length, seed, spec,
                       pages) if use_cache else None
    cached = _cache_load(path)
    if cached is not None:
        return cached

    rng = np.random.default_rng([seed, _stable_hash(workload), num_cores])
    kwargs = {}
    if "alpha" in spec and "alpha" in pattern.__code__.co_varnames:
        kwargs["alpha"] = spec["alpha"]
    vpn, off, work = pattern(rng, num_cores, length, pages, **kwargs)
    trace = {"vpn": vpn, "off": off, "work": work, "pages": pages}
    _cache_store(path, trace)
    return trace


def generate_traces(workloads: Sequence[str], num_cores: int,
                    length: int | None = None, seed: int | None = None,
                    preset=None,
                    use_cache: bool = True) -> List[Dict[str, np.ndarray]]:
    """Traces for a whole batch bucket (one per workload, same core count)
    — the unit :func:`repro.sim.simulate_batch` consumes."""
    return [generate_trace(w, num_cores, length, seed, preset, use_cache)
            for w in workloads]
