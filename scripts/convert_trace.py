#!/usr/bin/env python
"""Convert a real memory trace into a simulator trace (.npz).

Parses any format the ingest layer knows (ChampSim binary records,
Valgrind lackey text, csv — ``.xz``/``.gz`` transparently decompressed),
interleaves it over ``--cores``, and writes the simulator's
``{vpn, off, work, pages}`` dict as an ``.npz``.  Also prints the trace
characterization (footprint, page/line reuse, work density) that tells
you which Table-II synthetic workload it most resembles.

The npz is convenient for archiving/sharing, but the simulator does
not need it: every engine entry point accepts
``workload="trace:<path>[?opt=val&...]"`` directly (options below map
1:1 onto the spec-string options) and memoizes the parse through
``.trace_cache/``.

Usage:
  python scripts/convert_trace.py trace.champsim.xz --cores 4
  python scripts/convert_trace.py mem.csv --fmt csv --interleave thread \\
      --length 100000 --out mem.npz
  python scripts/convert_trace.py trace.lackey.gz --stats-only
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.workloads.ingest import (DEFAULT_GAP_CAP,  # noqa: E402
                                    DEFAULT_WORK_CLIP, INTERLEAVES,
                                    PARSERS, ingest_trace)


def stats(trace, page_bytes: int = 4096) -> dict:
    vpn, off, work = trace["vpn"], trace["off"], trace["work"]
    lines = vpn.astype(np.int64) * (page_bytes // 64) + off
    n = vpn.size
    return {
        "cores": vpn.shape[0],
        "accesses_per_core": vpn.shape[1],
        "footprint_pages": trace["pages"],
        "footprint_mb": round(trace["pages"] * page_bytes / 2**20, 1),
        "distinct_pages_touched": int(np.unique(vpn).size),
        "distinct_lines_touched": int(np.unique(lines).size),
        "line_reuse": round(1.0 - np.unique(lines).size / n, 3),
        "page_reuse": round(1.0 - np.unique(vpn).size / n, 3),
        "mean_work": round(float(work.mean()), 2),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("input", help="trace file (.xz/.gz auto-decompressed)")
    p.add_argument("--out", default=None,
                   help="output .npz (default: <input>.npz; "
                        "--stats-only skips writing)")
    p.add_argument("--cores", type=int, default=4)
    p.add_argument("--length", type=int, default=None,
                   help="clamp per-core accesses (default: whole file)")
    p.add_argument("--fmt", choices=sorted(PARSERS), default=None,
                   help="parser (default: inferred from the file name)")
    p.add_argument("--interleave", choices=INTERLEAVES,
                   default="round_robin")
    p.add_argument("--page-bytes", type=int, default=4096)
    p.add_argument("--work-clip", type=int, default=DEFAULT_WORK_CLIP)
    p.add_argument("--gap-cap", type=int, default=DEFAULT_GAP_CAP)
    p.add_argument("--no-cache", action="store_true",
                   help="bypass the .trace_cache memoization layer")
    p.add_argument("--stats-only", action="store_true",
                   help="print the characterization, write nothing")
    args = p.parse_args(argv)

    trace = ingest_trace(
        args.input, args.cores, length=args.length, fmt=args.fmt,
        interleave=args.interleave, page_bytes=args.page_bytes,
        work_clip=args.work_clip, gap_cap=args.gap_cap,
        use_cache=not args.no_cache)

    for k, v in stats(trace, args.page_bytes).items():
        print(f"{k}: {v}")

    if not args.stats_only:
        out = args.out or args.input + ".npz"
        np.savez(out, vpn=trace["vpn"], off=trace["off"],
                 work=trace["work"], pages=trace["pages"])
        print(f"wrote {out}")

    spec = f"trace:{args.input}"
    extras = []
    if args.fmt:
        extras.append(f"fmt={args.fmt}")
    if args.interleave != "round_robin":
        extras.append(f"interleave={args.interleave}")
    if args.page_bytes != 4096:
        extras.append(f"page_bytes={args.page_bytes}")
    if extras:
        spec += "?" + "&".join(extras)
    print(f'# replay directly: sweep({{"workload": ("{spec}",)}}) or '
          f'simulate_batch(mach, ["{spec}"])')
    return 0


if __name__ == "__main__":
    sys.exit(main())
