#!/usr/bin/env python
"""Relative-link checker for the repo's markdown docs.

Scans the given markdown files/directories for ``[text](target)``
links and verifies every *relative* target resolves to an existing file
or directory (external ``http(s)://``/``mailto:`` links and in-page
``#anchors`` are skipped; a ``path#anchor`` target checks the path).
No third-party dependencies — runs in the CI docs job.

Usage:
  python scripts/check_links.py README.md ROADMAP.md docs
"""
from __future__ import annotations

import os
import re
import sys
from typing import Iterable, List, Tuple

#: inline markdown links; deliberately simple — our docs don't use
#: reference-style links or parens-in-URLs
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def iter_md(paths: Iterable[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".md"))
        else:
            out.append(p)
    return out


def broken_links(md_file: str) -> List[Tuple[str, str]]:
    """(target, reason) for every broken relative link in one file."""
    with open(md_file, encoding="utf-8") as f:
        text = f.read()
    bad = []
    base = os.path.dirname(os.path.abspath(md_file))
    for target in _LINK.findall(text):
        if target.startswith(_SKIP) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            bad.append((target, f"missing: {resolved}"))
    return bad


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or ["README.md"]
    files = iter_md(paths)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    n_bad = 0
    for f in files:
        for target, reason in broken_links(f):
            print(f"{f}: broken link ({target}) — {reason}",
                  file=sys.stderr)
            n_bad += 1
    print(f"checked {len(files)} markdown files: "
          f"{n_bad} broken relative links")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
