#!/usr/bin/env python
"""Chaos replay CLI: run a named fault plan against the live stack and
print the recovery log.

Each plan drives the real code path (no mocks) under a deterministic
:class:`repro.util.resilience.FaultInjector`, then checks the
resilience invariant the plan exists to protect: injected faults may
cost retries, never answers.

  * ``cache_corrupt``  — trace-cache read AND write faults: the read
    fault quarantines the entry, the write fault degrades to
    cache-off; the regenerated trace must be bit-exact.
  * ``dispatch_hang``  — a sweep bucket's dispatch raises
    :class:`DispatchTimeout`; the watchdog clears the compiled-runner
    cache and retries once; SimResults must be bit-exact vs a clean
    run.
  * ``evict_storm``    — three mid-decode evictions in the serving
    engine; preempted requests re-prefill (prompt + generated-so-far)
    and every request's final tokens must match the fault-free run.

Usage:
  python scripts/chaos.py --plan cache_corrupt
  python scripts/chaos.py --plan dispatch_hang --seed 1
  python scripts/chaos.py --all
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np  # noqa: E402

from repro.util import resilience  # noqa: E402

_TRACE_KEYS = ("vpn", "off", "work")


def _plan_cache_corrupt(seed: int) -> bool:
    """Trace cache under read+write faults: quarantine, degrade,
    recompute — bit-exact either way."""
    from repro.workloads import generate_trace
    with tempfile.TemporaryDirectory() as tmp:
        os.environ["SIM_TRACE_CACHE"] = tmp
        try:
            kw = dict(cores=2, length=2048, seed=seed)
            clean = generate_trace("rnd", kw["cores"], length=kw["length"],
                                   seed=kw["seed"])
            inj = resilience.FaultInjector.from_plan("cache_corrupt",
                                                     seed=seed)
            with resilience.inject_faults(inj):
                # read fault -> quarantine + recompute; the recompute's
                # store then hits the write fault -> cache-off degrade
                faulted = generate_trace("rnd", kw["cores"],
                                         length=kw["length"],
                                         seed=kw["seed"])
        finally:
            del os.environ["SIM_TRACE_CACHE"]
    return all(np.array_equal(clean[k], faulted[k]) for k in _TRACE_KEYS)


def _plan_dispatch_hang(seed: int) -> bool:
    """One sweep bucket's dispatch 'hangs' (injected); the watchdog
    retries after clearing the compiled-runner cache."""
    from repro.sim._sweep import _RESULT_FIELDS, sweep
    grid = {"memory.latency": [100, 170]}
    clean = sweep(grid, preset="smoke", seed=seed)
    inj = resilience.FaultInjector.from_plan("dispatch_hang", seed=seed)
    with resilience.inject_faults(inj):
        faulted = sweep(grid, preset="smoke", seed=seed)
    return all(
        np.array_equal(getattr(clean.results.flat[i], f),
                       getattr(faulted.results.flat[i], f))
        for i in range(clean.results.size) for f in _RESULT_FIELDS)


def _serve_tokens(cfg, params, prompts, inj=None):
    from repro.serving import Request, ServeEngine
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, page_size=8)
    for i, pr in enumerate(prompts):
        eng.submit(Request(req_id=i, prompt=pr, max_new_tokens=6))
    if inj is not None:
        with resilience.inject_faults(inj):
            done = eng.run()
    else:
        done = eng.run()
    return {r.req_id: list(r.generated) for r in done}


def _plan_evict_storm(seed: int) -> bool:
    """Three mid-decode evictions; re-prefill makes tokens bit-exact."""
    import dataclasses

    import jax

    from repro.config import get_arch, smoke_variant
    from repro.models import init_params
    cfg = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]
    clean = _serve_tokens(cfg, params, prompts)
    inj = resilience.FaultInjector.from_plan("evict_storm", seed=seed)
    faulted = _serve_tokens(cfg, params, prompts, inj=inj)
    return clean == faulted


PLANS = {
    "cache_corrupt": _plan_cache_corrupt,
    "dispatch_hang": _plan_dispatch_hang,
    "evict_storm": _plan_evict_storm,
}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--plan", choices=sorted(PLANS),
                   help="named fault plan to replay")
    p.add_argument("--all", action="store_true",
                   help="replay every plan")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    names = sorted(PLANS) if args.all else ([args.plan] if args.plan
                                            else [])
    if not names:
        p.error("pick --plan NAME or --all")

    failed = []
    for name in names:
        resilience.recovery_events(clear=True)
        ok = PLANS[name](args.seed)
        events = resilience.recovery_events(clear=True)
        print(f"== plan {name}: {'BIT-EXACT' if ok else 'DIVERGED'} "
              f"({len(events)} recovery events)")
        for kind, detail in events:
            print(f"   {kind}: {detail}")
        if not ok:
            failed.append(name)
        if not events:
            print(f"   (no recovery events — plan {name} injected "
                  f"nothing?)")
            failed.append(name)
    if failed:
        print(f"CHAOS FAILED: {sorted(set(failed))}", file=sys.stderr)
        return 1
    print("chaos: every fault plan recovered bit-exactly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
