"""Quickstart: the NDPage reproduction in four acts, on CPU, in ~2 minutes.

  1. run the architectural simulator on one workload (the paper's core
     result: NDPage > ECH > radix on an NDP machine),
  2. inspect the two NDPage mechanisms on the serving side: flattened
     block-table translation + occupancy-driven flattening,
  3. decode with a paged KV cache (flat vs radix tables, same outputs),
  4. take one training step on a reduced assigned architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, smoke_variant
from repro.configs.ndp_sim import ndp_machine
from repro.core import block_table as BT
from repro.core.kv_page_manager import KVPageManager
from repro.models import init_params
from repro.serving import greedy_reference
from repro.sim import simulate
from repro.workloads import generate_trace


def act1_simulator():
    print("=== 1. NDPage vs prior mechanisms (2-core NDP, GUPS) ===")
    res = simulate(ndp_machine(2), generate_trace("rnd", 2, 4000))
    for mech, sp in res.speedup_vs().items():
        print(f"   {mech:10s} speedup vs radix: {sp:.3f}")
    ptw = res.avg_ptw_latency()
    print(f"   PTW latency: radix={ptw[0]:.0f}cyc ndpage={ptw[3]:.0f}cyc")


def act2_tables():
    print("=== 2. Flattened block tables + occupancy decision ===")
    kvm = KVPageManager(num_pages=64, page_size=4, max_seqs=4, max_len=32)
    kvm.add_sequence(0, prompt_len=14)
    kvm.add_sequence(1, prompt_len=2)
    print(f"   occupancy={kvm.occupancy():.2f} -> mode={kvm.preferred_mode()}")
    flat = kvm.flat_table([0, 1])
    radix = kvm.radix_table([0, 1])
    same = bool((BT.flatten_radix(radix) == flat).all())
    print(f"   flatten(radix) == flat table: {same}")
    print(f"   table bytes: flat={BT.table_bytes(flat, BT.FLAT)} "
          f"radix={BT.table_bytes(radix, BT.RADIX)}")


def act3_paged_decode():
    print("=== 3. Paged decode: translation is transparent ===")
    cfg = dataclasses.replace(smoke_variant(get_arch("gemma3-1b")),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    outs = {m: greedy_reference(cfg, params, prompt, 5, kv_mode=m,
                                max_len=32, page_size=4)
            for m in ("dense", "paged_flat", "paged_radix")}
    for m, o in outs.items():
        print(f"   {m:12s}: {o}")
    assert outs["dense"] == outs["paged_flat"] == outs["paged_radix"]


def act4_train():
    print("=== 4. One train step on a reduced assigned arch ===")
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import init_train_state, make_train_step
    cfg = dataclasses.replace(smoke_variant(get_arch("granite-moe-1b-a400m")),
                              dtype="float32")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=4)
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        print(f"   step {i}: loss={float(metrics['loss']):.3f} "
              f"aux={float(metrics['aux']):.3f}")


if __name__ == "__main__":
    act1_simulator()
    act2_tables()
    act3_paged_decode()
    act4_train()
    print("quickstart OK")
