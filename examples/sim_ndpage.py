"""Reproduce the paper's evaluation on a chosen workload set.

Runs the five address-translation mechanisms on NDP machines at 1/4/8 cores
and prints the Fig-12/13/14 speedup table plus the Fig-4/5 characterization.

Usage:
  PYTHONPATH=src python examples/sim_ndpage.py [--workloads rnd,bfs,dlrm]
      [--cores 1,4] [--trace-len 8000]
  PYTHONPATH=src python examples/sim_ndpage.py --sweep pwc_size
      # any preset from repro.configs.ndp_sim.SWEEPS — one batched
      # dispatch per compiled-shape bucket, NDPage speedup per grid point
"""
import argparse

import numpy as np

from repro.configs.ndp_sim import SWEEPS, WORKLOADS, cpu_machine, ndp_machine
from repro.sim import simulate
from repro.workloads import generate_trace


def run_sweep(name: str, trace_len: int | None) -> None:
    """Run one named sensitivity sweep and print its speedup grid."""
    from repro.sim import sweep
    r = sweep(name, trace_len=trace_len)
    s = r.stats
    print(f"sweep {name!r}: {s['points']} points -> {s['buckets']} "
          f"shape buckets, {s['runner_compiles']} runner compiles, "
          f"{s['wall_s']:.1f}s")
    axis, vals = next(iter(r.axes.items()))      # the swept axis
    wls = r.axes.get("workload", ("?",))
    print(f"{'ndpage speedup':>16s} " + " ".join(f"{w:>7s}" for w in wls))
    for v in vals:
        sub = r.select(**{axis: v})
        if axis == "mechs":
            mech = next(m for m in v if m.startswith("ndpage"))
            row, label = sub.map(
                lambda x: x.speedup_vs()[mech]), f"{mech}"
        else:
            row, label = sub.speedup("ndpage"), f"{axis}={v}"
        print(f"{label:>16s} " + " ".join(f"{x:7.3f}"
                                          for x in np.atleast_1d(row)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workloads", default="rnd,bfs,dlrm")
    ap.add_argument("--cores", default="1,4")
    ap.add_argument("--trace-len", type=int, default=6000)
    ap.add_argument("--sweep", default=None, choices=sorted(SWEEPS),
                    help="run a named sensitivity sweep instead of the "
                         "figure tables")
    args = ap.parse_args()
    if args.sweep:
        run_sweep(args.sweep, args.trace_len)
        return
    names = [w for w in args.workloads.split(",") if w in WORKLOADS]
    cores = [int(c) for c in args.cores.split(",")]

    for c in cores:
        print(f"\n=== {c}-core NDP system ===")
        print(f"{'workload':8s} {'ech':>7s} {'huge':>7s} {'ndpage':>7s} "
              f"{'ideal':>7s} {'PTW(radix)':>11s} {'overhead':>9s}")
        acc = {m: [] for m in ("ech", "hugepage", "ndpage", "ideal")}
        for w in names:
            r = simulate(ndp_machine(c), generate_trace(w, c,
                                                        args.trace_len))
            sp = r.speedup_vs()
            for m in acc:
                acc[m].append(sp[m])
            print(f"{w:8s} {sp['ech']:7.3f} {sp['hugepage']:7.3f} "
                  f"{sp['ndpage']:7.3f} {sp['ideal']:7.3f} "
                  f"{r.avg_ptw_latency()[0]:11.1f} "
                  f"{r.translation_fraction()[0]:9.3f}")
        print(f"{'mean':8s} " + " ".join(
            f"{np.mean(acc[m]):7.3f}" for m in acc))

    print("\n=== NDP vs CPU (4-core, first workload) ===")
    w = names[0]
    nd = simulate(ndp_machine(4), generate_trace(w, 4, args.trace_len))
    cp = simulate(cpu_machine(4), generate_trace(w, 4, args.trace_len))
    print(f"PTW latency : NDP={nd.avg_ptw_latency()[0]:.1f}cyc "
          f"CPU={cp.avg_ptw_latency()[0]:.1f}cyc")
    print(f"translation : NDP={nd.translation_fraction()[0]:.3f} "
          f"CPU={cp.translation_fraction()[0]:.3f}")


if __name__ == "__main__":
    main()
