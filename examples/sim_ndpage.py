"""Reproduce the paper's evaluation on a chosen workload set.

Runs the five address-translation mechanisms on NDP machines at 1/4/8 cores
and prints the Fig-12/13/14 speedup table plus the Fig-4/5 characterization.

Usage:
  PYTHONPATH=src python examples/sim_ndpage.py [--workloads rnd,bfs,dlrm]
      [--cores 1,4] [--trace-len 8000]
"""
import argparse

import numpy as np

from repro.configs.ndp_sim import WORKLOADS, cpu_machine, ndp_machine
from repro.sim import simulate
from repro.workloads import generate_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workloads", default="rnd,bfs,dlrm")
    ap.add_argument("--cores", default="1,4")
    ap.add_argument("--trace-len", type=int, default=6000)
    args = ap.parse_args()
    names = [w for w in args.workloads.split(",") if w in WORKLOADS]
    cores = [int(c) for c in args.cores.split(",")]

    for c in cores:
        print(f"\n=== {c}-core NDP system ===")
        print(f"{'workload':8s} {'ech':>7s} {'huge':>7s} {'ndpage':>7s} "
              f"{'ideal':>7s} {'PTW(radix)':>11s} {'overhead':>9s}")
        acc = {m: [] for m in ("ech", "hugepage", "ndpage", "ideal")}
        for w in names:
            r = simulate(ndp_machine(c), generate_trace(w, c,
                                                        args.trace_len))
            sp = r.speedup_vs()
            for m in acc:
                acc[m].append(sp[m])
            print(f"{w:8s} {sp['ech']:7.3f} {sp['hugepage']:7.3f} "
                  f"{sp['ndpage']:7.3f} {sp['ideal']:7.3f} "
                  f"{r.avg_ptw_latency()[0]:11.1f} "
                  f"{r.translation_fraction()[0]:9.3f}")
        print(f"{'mean':8s} " + " ".join(
            f"{np.mean(acc[m]):7.3f}" for m in acc))

    print("\n=== NDP vs CPU (4-core, first workload) ===")
    w = names[0]
    nd = simulate(ndp_machine(4), generate_trace(w, 4, args.trace_len))
    cp = simulate(cpu_machine(4), generate_trace(w, 4, args.trace_len))
    print(f"PTW latency : NDP={nd.avg_ptw_latency()[0]:.1f}cyc "
          f"CPU={cp.avg_ptw_latency()[0]:.1f}cyc")
    print(f"translation : NDP={nd.translation_fraction()[0]:.3f} "
          f"CPU={cp.translation_fraction()[0]:.3f}")


if __name__ == "__main__":
    main()
