"""Serve a small model with batched requests over the paged KV cache.

Demonstrates the NDPage serving path end-to-end: continuous batching, page
allocation, occupancy-driven table flattening, and the translation cache.

Usage:
  PYTHONPATH=src python examples/serve_paged.py [--arch gemma3-1b]
      [--requests 12] [--table-mode auto|paged_flat|paged_radix]
      [--costed]

``--costed`` attaches the simulator-derived translation cost model
(pinned table — no simulator run) and prints tokens/sec under every
translation mechanism, the paper's end-to-end claim at the serving
layer (see docs/serving.md).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import get_arch, smoke_variant
from repro.models import init_params
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--table-mode", default="auto",
                    choices=["auto", "paged_flat", "paged_radix"])
    ap.add_argument("--costed", action="store_true",
                    help="price translations with the pinned cost "
                         "model and report per-mechanism tokens/sec")
    args = ap.parse_args()

    cfg = dataclasses.replace(smoke_variant(get_arch(args.arch)),
                              dtype="float32")
    print(f"arch={args.arch} (reduced config), vocab={cfg.vocab_size}")
    params = init_params(cfg, jax.random.PRNGKey(0))

    mode = None if args.table_mode == "auto" else args.table_mode
    cost_model = None
    if args.costed:
        from repro.sim import TranslationCostModel
        cost_model = TranslationCostModel.pinned()
    eng = ServeEngine(cfg, params, max_batch=args.max_batch, max_len=96,
                      page_size=8, table_mode=mode,
                      cost_model=cost_model)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              rng.integers(4, 12)).astype(np.int32)
        eng.submit(Request(req_id=i, prompt=prompt,
                           max_new_tokens=args.new_tokens))
    done = eng.run()
    dt = time.time() - t0

    toks = sum(len(r.generated) for r in done)
    print(f"completed {len(done)} requests / {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s on CPU smoke model)")
    print(f"scheduler: {eng.sched.stats}")
    print(f"kv manager: {eng.kvm.stats}, occupancy now "
          f"{eng.kvm.occupancy():.2f}")
    print(f"translation cache hit rate: {eng.sched.tcache.hit_rate:.2%}")
    for r in done[:3]:
        print(f"  req {r.req_id}: prompt={r.prompt.tolist()} -> "
              f"{r.generated}")
    if cost_model is not None:
        rep = eng.throughput()
        print(f"translation-costed throughput "
              f"(model={cost_model.machine}, {cost_model.source}):")
        for m, v in rep["tokens_per_sec"].items():
            print(f"  {m:10s} {v:14.0f} tok/s  "
                  f"trans={rep['translation_cycles'][m]:.0f}cyc")


if __name__ == "__main__":
    main()
