"""End-to-end training driver: train a ~100M-class model for a few hundred
steps with checkpointing, fault tolerance, and restart-exactness.

By default trains a ~45M-param slice of the internlm2 family (laptop-scale)
for 200 steps; any assigned arch id works via --arch (reduced configs).

Usage:
  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]
      [--arch internlm2-1.8b] [--full-width]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import get_arch, smoke_variant
from repro.train.data import SyntheticLM, add_modality_stubs
from repro.train.fault_tolerance import FaultConfig, GuardedTrainer
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


def build_cfg(args):
    base = get_arch(args.arch)
    if args.full_width:
        # ~100M-class: 8 layers at 768 wide
        cfg = dataclasses.replace(
            base, name=base.name + "-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32_000, remat=False, fsdp=False, dtype="float32",
            layer_pattern=base.layer_pattern[:1], prefix_pattern=())
    else:
        cfg = dataclasses.replace(smoke_variant(base), dtype="float32")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params instead of the smoke config")
    args = ap.parse_args()

    cfg = build_cfg(args)
    n_params = cfg.param_count()
    print(f"arch={cfg.name}  params~{n_params / 1e6:.1f}M  "
          f"steps={args.steps}")

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch)

    guard = GuardedTrainer(
        FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50), step_fn, state)
    guard.install_signal_handler()
    if args.resume and guard.maybe_restore():
        print(f"resumed from step {guard.step}")

    t0 = time.time()
    while guard.step < args.steps:
        raw = data.batch_at(guard.step)
        raw = add_modality_stubs(raw, cfg, seed=guard.step)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        metrics = guard.run_step(batch)
        if metrics is None:
            print("stopped by signal; emergency checkpoint saved")
            return
        if guard.step % 20 == 0 or guard.step == args.steps:
            tps = args.batch * args.seq_len / max(guard.stats.step_ema_s,
                                                  1e-9)
            print(f"step {guard.step:4d}  loss={float(metrics['loss']):.4f}"
                  f"  lr={float(metrics['lr']):.2e}  {tps:,.0f} tok/s"
                  f"  retries={guard.stats.retries}")
    print(f"done in {time.time() - t0:.0f}s; "
          f"stragglers={guard.stats.straggler_steps}, "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
