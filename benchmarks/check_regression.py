"""Perf-regression gate: compare a fresh BENCH_sim.json against the
committed baseline (benchmarks/perf_baseline.json).

Fails (exit 1) when aggregate engine throughput regresses by more than
``--max-regression`` (default 25%) — the nightly CI job runs this right
after the benchmark smoke, so a PR that slows the simulator fleet turns
the run red instead of silently drifting.  The gate compares
``steps_per_sec_steady`` (compile time excluded) when both sides have
it, so an XLA-cache miss — every ``src/repro`` change invalidates the
CI cache key — cannot masquerade as an engine regression; it falls back
to ``steps_per_sec`` for older baselines.

The env fingerprint is a RUNNER CLASS, not raw hardware: CI sets
``PERF_RUNNER_CLASS`` (nightly and refresh-baseline use the same
value), local runs default to ``cpu<count>``.  Matching class + matching
SIM_DEVICES arms the gate (fail-loud); anything else skips with a
notice, because comparing against a baseline from different hardware
gates the machine, not the change.

Refresh the baseline after an intentional perf change with::

    python benchmarks/run.py --fast --sim-only
    python benchmarks/check_regression.py --update

(or dispatch the ``refresh-baseline`` CI workflow, which runs both on
the hosted-runner class and uploads the artifact to commit).  The
committed baseline records where it was actually measured in
``measured_on``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _env_fingerprint() -> dict:
    """What the throughput numbers depend on besides the code: comparing
    against a baseline from different hardware gates the machine, not
    the change.  The class is an explicit label (PERF_RUNNER_CLASS, set
    by CI) so a baseline built FOR the hosted-runner class arms the
    nightly gate; without the label it falls back to the host's CPU
    count, keeping ad-hoc local comparisons honest."""
    return {"class": (os.environ.get("PERF_RUNNER_CLASS")
                      or f"cpu{os.cpu_count()}"),
            "sim_devices": os.environ.get("SIM_DEVICES", "")}

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_CURRENT = os.path.join(_ROOT, "BENCH_sim.json")
DEFAULT_BASELINE = os.path.join(_ROOT, "benchmarks", "perf_baseline.json")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--current", default=DEFAULT_CURRENT,
                   help="fresh BENCH_sim.json (from benchmarks/run.py)")
    p.add_argument("--baseline", default=DEFAULT_BASELINE,
                   help="committed baseline json")
    p.add_argument("--max-regression", type=float, default=0.25,
                   help="allowed fractional steps_per_sec drop (0.25=25%%)")
    p.add_argument("--update", action="store_true",
                   help="rewrite the baseline from --current and exit")
    args = p.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)

    if args.update:
        base = {k: cur[k] for k in
                ("preset", "trace_len", "num_sims", "steps_per_sec",
                 "steps_per_sec_steady", "sim_wall_s_total",
                 "figures_wall_s") if k in cur}
        base["stages"] = cur.get("stages", {})
        base["env"] = _env_fingerprint()
        # provenance: where the numbers were ACTUALLY measured (the env
        # class above is the intended comparison target)
        base["measured_on"] = {"cpu_count": os.cpu_count(),
                               "sim_devices": os.environ.get(
                                   "SIM_DEVICES", "")}
        with open(args.baseline, "w") as f:
            json.dump(base, f, indent=1)
        print(f"baseline updated: {args.baseline} "
              f"(steps_per_sec={base['steps_per_sec']})")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)

    if cur.get("preset") != base.get("preset"):
        print(f"preset mismatch (current={cur.get('preset')} "
              f"baseline={base.get('preset')}); skipping gate")
        return 0
    env = _env_fingerprint()
    if base.get("env") != env:
        print(f"environment mismatch (current={env} "
              f"baseline={base.get('env')}); skipping gate — refresh the "
              "baseline on this runner class with --update")
        return 0

    metric = ("steps_per_sec_steady"
              if "steps_per_sec_steady" in cur
              and "steps_per_sec_steady" in base else "steps_per_sec")
    b, c = float(base[metric]), float(cur[metric])
    drop = 1.0 - c / b if b else 0.0
    print(f"{metric}: baseline={b:.1f} current={c:.1f} "
          f"delta={-drop * 100:+.1f}%")
    for k in ("figures_wall_s", "sim_wall_s_total"):
        if k in cur and k in base:
            print(f"{k}: baseline={base[k]} current={cur[k]}")
    if drop > args.max_regression:
        print(f"FAIL: {metric} regressed {drop * 100:.1f}% "
              f"(limit {args.max_regression * 100:.0f}%)")
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
