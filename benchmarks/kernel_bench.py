"""Microbenchmarks for the serving/kernel layer (CPU: jnp reference path;
the same harness drives the Pallas kernels on real TPU).

Covers the framework-side table of the reproduction: translation cost per
decode step for flat (NDPage) vs radix (2-level) block tables vs dense
(no-translation ideal), plus engine throughput and simulator throughput.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_table as BT


def _time(fn, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_translation() -> List[Tuple[str, float, str]]:
    """Table-translate cost: ONE gather (flat) vs TWO dependent gathers
    (radix) at serving scale — the kernel-visible half of NDPage."""
    rows = []
    for b, maxp in ((64, 512), (256, 512), (64, 8192)):
        flat = jnp.asarray(
            np.random.default_rng(0).permutation(b * maxp)
            .reshape(b, maxp).astype(np.int32))
        radix = BT.radix_from_flat(flat, leaf_size=16)
        f = jax.jit(lambda t: BT.translate_all(t, BT.FLAT))
        r = jax.jit(lambda t: BT.translate_all(t, BT.RADIX))
        tf = _time(f, flat)
        tr = _time(r, radix)
        rows.append((f"translate_flat_b{b}_p{maxp}", tf,
                     f"radix={tr:.1f}us ratio={tr / tf:.2f}x"))
    return rows


def bench_paged_attention() -> List[Tuple[str, float, str]]:
    from repro.kernels import ref
    rows = []
    for b, h, kh, d, page, maxp in ((8, 16, 8, 64, 16, 32),
                                    (16, 16, 8, 64, 16, 64)):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        n = b * maxp + 1
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        kp = jax.random.normal(ks[1], (n, page, kh, d), jnp.float32)
        vp = jax.random.normal(ks[2], (n, page, kh, d), jnp.float32)
        tab = jnp.asarray(np.random.default_rng(0).permutation(n - 1)[
            : b * maxp].reshape(b, maxp).astype(np.int32))
        lens = jnp.full((b,), page * maxp - 3, jnp.int32)
        fn = jax.jit(lambda *a: ref.paged_attention_ref(*a))
        us = _time(fn, q, kp, vp, tab, lens)
        toks = b * page * maxp
        rows.append((f"paged_attn_b{b}_kv{page * maxp}", us,
                     f"{toks / us:.1f} kv-tokens/us (jnp ref path)"))
    return rows


def bench_flash_attention() -> List[Tuple[str, float, str]]:
    from repro.models.attention import blockwise_attention
    rows = []
    for b, s, h, kh, d in ((2, 2048, 8, 4, 64),):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: blockwise_attention(
            q, k, v, causal=True, q_chunk=512, kv_chunk=512))
        us = _time(fn, q, k, v, iters=5)
        flops = 4 * b * h * s * s * d / 2
        rows.append((f"blockwise_attn_s{s}", us,
                     f"{flops / us / 1e6:.2f} GFLOP/s (cpu jnp)"))
    return rows


def bench_serve_engine() -> List[Tuple[str, float, str]]:
    import dataclasses
    from repro.config import get_arch, smoke_variant
    from repro.models import init_params
    from repro.serving import Request, ServeEngine

    cfg = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    for mode in (BT.FLAT, BT.RADIX):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=64, page_size=8,
                          table_mode=mode)
        rng = np.random.default_rng(0)
        for i in range(8):
            eng.submit(Request(req_id=i,
                               prompt=rng.integers(1, 200, 6)
                               .astype(np.int32),
                               max_new_tokens=8))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve_engine_{mode}", dt / max(toks, 1) * 1e6,
                     f"{toks} tokens, tcache_hit={eng.sched.tcache.hit_rate:.2f}"))
    return rows


def bench_simulator() -> List[Tuple[str, float, str]]:
    from repro.configs.ndp_sim import ndp_machine
    from repro.sim import simulate
    from repro.workloads import generate_trace
    tr = generate_trace("rnd", 4, 4000)
    t0 = time.perf_counter()
    simulate(ndp_machine(4), tr)          # includes compile
    t1 = time.perf_counter()
    simulate(ndp_machine(4), generate_trace("rnd", 4, 4000, seed=1))
    t2 = time.perf_counter()
    return [("simulator_4c_4k_accesses", (t2 - t1) * 1e6,
             f"compile+run={t1 - t0:.1f}s; steady {4000 * 4 * 5 / (t2 - t1):.0f} "
             "access-mech-sims/s")]


def run_all() -> List[Tuple[str, float, str]]:
    rows = []
    for fn in (bench_translation, bench_paged_attention,
               bench_flash_attention, bench_serve_engine, bench_simulator):
        rows.extend(fn())
    return rows
