"""Mechanism-zoo benchmark: four related-work translation designs
through the full pipeline, judged against the searched NDPage point.

The zoo (all registered in :mod:`repro.sim.mechanisms`, all riding the
SAME batched engine — mechanism identity is value-only, so the whole
comparison is ONE compile):

  * ``victima``      — Victima-style cache-as-TLB: a large second TLB
    level carved out of L2-cache capacity (``ctlb_kb``), probed
    serially after an L2-TLB miss; a hit short-circuits the radix walk.
  * ``picorel``      — Picorel/NMP-style inverted-hash translation with
    a direct-segment fast path: one hashed PTE access, no radix levels,
    segment-resident pages skip translation entirely.
  * ``coda``         — CODA-style co-location-aware mapping: walks and
    data of co-located pages land in the LOCAL stack, cutting the
    multi-stack hop penalty to a 10% residual.
  * ``range_table``  — range/segment-table translation: binary-search
    over contiguous-run descriptors, log2(ranges) lookup scaling.

Four phases, each a section of the ``"zoo"`` payload merged into
``BENCH_sim.json`` (never clobbering the figures/sweeps/serving/search
sections):

  * ``sim``      — full-zoo speedup table over the six synthetic
    workloads PLUS the two committed real-trace fixtures, one
    ``simulate_batch`` dispatch (compile count == bucket count == 1
    asserted via the runner cache).
  * ``serving``  — translation-costed paged-KV serving with the zoo
    cost table (segment/inverted orgs price their own PTE-line counts).
  * ``search``   — the ``"zoo"`` design space: mechanism choice as a
    genome knob, searched jointly with ctlb/PWC sizing.
  * ``collisions`` — Picorel's open-addressed inverted table on the
    fixture footprints: load factor vs probe count.

The ``verdict`` section states explicitly where each design beats or
loses to ``ndpage_search`` and why.  Structural checks (ideal is the
upper bound everywhere, Victima's serial-probe overhead is bounded,
Picorel beats the radix baseline, serving completes under every
mechanism) fail the run.

Usage:
  python benchmarks/sim_zoo.py [--fast]
  python benchmarks/run.py --zoo            # same, as a stage
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

Row = Tuple[str, float, str]

#: every mechanism in the comparison, paper set + zoo, one M axis
ZOO_SIM_MECHS = ("radix", "ech", "hugepage", "ndpage", "ndpage_search",
                 "victima", "picorel", "coda", "range_table", "ideal")
#: the serving cost table's mechs (serving reports ndpage vs the zoo)
ZOO_SERVE_MECHS = ("radix", "ndpage", "ndpage_search", "victima",
                   "picorel", "coda", "range_table", "ideal")
#: the reference point every zoo design is judged against
REFERENCE = "ndpage_search"


def _zoo_workloads() -> Tuple[str, ...]:
    from repro.configs.ndp_sim import SEARCH_FIXTURES, SWEEP_WORKLOADS
    return SWEEP_WORKLOADS + SEARCH_FIXTURES


def _wl_label(wl: str) -> str:
    if wl.startswith("trace:"):
        base = os.path.basename(wl[len("trace:"):].partition("?")[0])
        return base.split(".")[0]
    return wl


def run_zoo_sim(fast: bool) -> Tuple[List[Row], Dict]:
    """Phase 1: the full zoo on the zoo machine over synthetics + real
    fixtures — ONE batched dispatch, ONE compile."""
    from repro.configs.ndp_sim import PRESETS, zoo_machine
    from repro.sim.simulator import (runner_cache_info, simulate_batch)

    preset = PRESETS["smoke" if fast else "full"]
    mach = zoo_machine(4)
    wls = _zoo_workloads()
    from repro.workloads import generate_trace
    traces = [wl if wl.startswith("trace:")
              else generate_trace(wl, mach.num_cores, preset=preset)
              for wl in wls]

    info0 = runner_cache_info()
    t0 = time.perf_counter()
    results = simulate_batch(mach, traces, mechs=ZOO_SIM_MECHS,
                             chunk=preset.chunk)
    wall = time.perf_counter() - t0
    compiles = runner_cache_info().misses - info0.misses

    rows: List[Row] = []
    speedups: Dict[str, Dict[str, float]] = {}
    for wl, res in zip(wls, results):
        sp = res.speedup_vs()
        label = _wl_label(wl)
        speedups[label] = {m: round(float(sp[m]), 4)
                           for m in ZOO_SIM_MECHS}
        rows.append((f"zoo_sim_{label}", 0.0,
                     " ".join(f"{m}={sp[m]:.3f}"
                              for m in ZOO_SIM_MECHS if m != "radix")))

    arr = {m: np.array([speedups[_wl_label(w)][m] for w in wls])
           for m in ZOO_SIM_MECHS}
    checks = {
        # ONE shape x ONE walk-fn tuple => one bucket; a warm
        # persistent cache can only lower the count
        "one_compile_one_bucket": compiles <= 1,
        "ideal_upper_bound": bool(all(
            (arr["ideal"] >= arr[m] - 1e-6).all()
            for m in ZOO_SIM_MECHS)),
        "victima_probe_overhead_bounded":
            bool((arr["victima"] >= 0.9).all()),
        "picorel_beats_radix": bool((arr["picorel"] >= 1.0).all()),
        "fixtures_covered": len(wls) == len(results),
    }
    rows.append(("zoo_sim_engine", wall * 1e6 / len(wls),
                 f"{len(wls)}workloads 1bucket {compiles}compiles "
                 f"{wall:.1f}s"))
    section = {"machine": mach.name, "preset": preset.name,
               "mechs": list(ZOO_SIM_MECHS),
               "workloads": [_wl_label(w) for w in wls],
               "speedup_vs_radix": speedups,
               "runner_compiles": compiles, "buckets": 1,
               "wall_s": round(wall, 2), "checks": checks}
    return rows, section


def run_zoo_serving(fast: bool, seed: int = 0) -> Tuple[List[Row], Dict]:
    """Phase 2: translation-costed serving with the zoo cost table —
    the segment/inverted organizations price their own PTE-line
    accounting in the metered decode loop."""
    from benchmarks.serving_translation import SMOKE_MIXES, _engine_factory
    from repro.configs.ndp_sim import zoo_machine
    from repro.serving import Request, ServeEngine
    from repro.sim.cost_model import TranslationCostModel
    from repro.sim.simulator import runner_cache_info

    info0 = runner_cache_info()
    model = TranslationCostModel.from_sim(zoo_machine(4),
                                          mechs=ZOO_SERVE_MECHS)
    cost_compiles = runner_cache_info().misses - info0.misses

    cfg, params = _engine_factory()
    mix = SMOKE_MIXES["decode_heavy"]
    rng = np.random.default_rng(seed)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96,
                      page_size=8, cost_model=model)
    t0 = time.perf_counter()
    for i in range(mix["n_requests"]):
        lo, hi = mix["prompt"]
        prompt = rng.integers(1, cfg.vocab_size,
                              rng.integers(lo, hi)).astype(np.int32)
        eng.submit(Request(req_id=i, prompt=prompt,
                           max_new_tokens=mix["new_tokens"]))
    done = eng.run()
    wall = time.perf_counter() - t0
    rep = eng.throughput()
    tps = rep["tokens_per_sec"]

    rows: List[Row] = [
        (f"zoo_serving_{m}", 0.0,
         f"{tps[m]:.0f} tok/s "
         f"trans={rep['translation_cycles'][m]:.0f}cyc org="
         f"{model.costs[model.mechs.index(m)].org}")
        for m in model.mechs]
    checks = {
        "ideal_upper_bound": bool(all(tps["ideal"] >= v - 1e-9
                                      for v in tps.values())),
        "all_completed": len(done) == mix["n_requests"],
        "every_mech_priced": set(model.mechs) == set(ZOO_SERVE_MECHS),
    }
    rows.append(("zoo_serving_check", wall * 1e6,
                 f"{'OK' if all(checks.values()) else 'FAIL'} {checks}"))
    section = {
        "machine": model.machine, "mechs": list(model.mechs),
        "orgs": {m: model.costs[model.mechs.index(m)].org
                 for m in model.mechs},
        "cost_model_compiles": cost_compiles,
        "tokens_per_sec": {m: round(v, 1) for m, v in tps.items()},
        "translation_cycles": {
            m: round(v, 1)
            for m, v in rep["translation_cycles"].items()},
        "wall_s": round(wall, 2), "checks": checks,
    }
    return rows, section


def run_zoo_search() -> Tuple[List[Row], Dict]:
    """Phase 3: the ``"zoo"`` design space — mechanism membership is a
    genome knob searched jointly with ctlb/PWC sizing."""
    from repro.sim import search

    result = search("zoo")
    p = result.provenance
    rows: List[Row] = []
    for c in result.frontier:
        o = c.objectives
        rows.append((f"zoo_search_front_{c.mech}", 0.0,
                     f"speedup={o['mean_speedup']:.4f} "
                     f"sram={o['sram_kb']:g}KB "
                     f"worst_ptw={o['worst_ptw']:.1f}cyc"))
    frontier_mechs = sorted({c.mech for c in result.frontier})
    checks = {
        "frontier_nonempty": bool(result.frontier),
        "compile_bound":
            p["runner_compiles"] <= p["distinct_buckets"],
    }
    rows.append(("zoo_search_engine",
                 p["wall_s"] * 1e6 / max(p["evaluated"], 1),
                 f"{p['evaluated']}cands frontier_mechs="
                 f"{','.join(frontier_mechs)} "
                 f"{p['runner_compiles']}compiles {p['wall_s']:.1f}s"))
    section = {
        "space": "zoo", "evaluated": p["evaluated"],
        "runner_compiles": p["runner_compiles"],
        "frontier_mechs": frontier_mechs,
        "frontier": [c.to_json_dict() for c in result.frontier],
        "wall_s": round(p["wall_s"], 2), "checks": checks,
    }
    return rows, section


def run_collisions() -> Tuple[List[Row], Dict]:
    """Phase 4: Picorel's open-addressed inverted table on the real
    fixture footprints — the hash-collision cost its single-access
    latency model abstracts, reported so the abstraction is visible."""
    from repro.configs.ndp_sim import SEARCH_FIXTURES
    from repro.core.page_table import inverted_table_insert
    from repro.workloads import generate_trace

    rows: List[Row] = []
    per_fix: Dict[str, Dict] = {}
    for wl in SEARCH_FIXTURES:
        tr = generate_trace(wl, 4)
        vpns = np.unique(np.asarray(tr["vpn"]))
        # size the table one doubling above the footprint, as a real
        # inverted page table would be provisioned
        log2_slots = max(int(np.ceil(np.log2(max(len(vpns), 2)))) + 1, 4)
        _, probes = inverted_table_insert(vpns, log2_slots=log2_slots)
        label = _wl_label(wl)
        stats = {"footprint_pages": int(len(vpns)),
                 "log2_slots": log2_slots,
                 "load_factor": round(len(vpns) / (1 << log2_slots), 4),
                 "mean_extra_probes": round(float(probes.mean()), 4),
                 "max_extra_probes": int(probes.max()),
                 "collision_rate":
                     round(float((probes > 0).mean()), 4)}
        per_fix[label] = stats
        rows.append((f"zoo_collisions_{label}", 0.0,
                     f"load={stats['load_factor']:.3f} "
                     f"mean_extra_probes="
                     f"{stats['mean_extra_probes']:.3f} "
                     f"collisions={stats['collision_rate']:.1%}"))
    ok = all(s["mean_extra_probes"] < 2.0 for s in per_fix.values())
    checks = {"probe_chains_short_at_half_load": ok}
    rows.append(("zoo_collisions_check", 0.0,
                 f"mean extra probes < 2 at <=50% load: "
                 f"{'OK' if ok else 'FAIL'}"))
    return rows, {"fixtures": per_fix, "checks": checks}


def build_verdict(sim_section: Dict) -> Dict:
    """Where each zoo design beats / loses to ``ndpage_search`` — the
    explicit judgement the comparison exists to produce."""
    from repro.sim.mechanisms import ZOO_MECHS
    sp = sim_section["speedup_vs_radix"]
    wls = sim_section["workloads"]
    out: Dict[str, Dict] = {}
    reasons = {
        "victima": ("serial ctlb probe is pure overhead when the "
                    "workload either fits the L2 TLB or blows past the "
                    "cache-as-TLB reach; wins only in the in-between "
                    "reuse band"),
        "picorel": ("one hashed PTE access beats a 4-level walk "
                    "whenever PWC locality is poor; ignores hash "
                    "collisions (see the collisions section)"),
        "coda": ("co-location only discounts the multi-stack hop "
                 "penalty, a small slice of total walk latency here"),
        "range_table": ("binary-search depth tracks fragmentation: "
                        "competitive on contiguous footprints, pays on "
                        "fragmented ones"),
    }
    for m in ZOO_MECHS:
        wins = [w for w in wls if sp[w][m] > sp[w][REFERENCE] + 1e-4]
        loses = [w for w in wls if sp[w][m] < sp[w][REFERENCE] - 1e-4]
        ratio = float(np.mean([sp[w][m] / sp[w][REFERENCE]
                               for w in wls]))
        out[m] = {
            "beats_ndpage_search_on": wins,
            "loses_to_ndpage_search_on": loses,
            "mean_relative_speedup": round(ratio, 4),
            "verdict": (f"{'beats' if ratio > 1 else 'loses to'} "
                        f"{REFERENCE} on average "
                        f"({ratio:.3f}x): {reasons[m]}"),
        }
    return out


def run_all(fast: bool = True, seed: int = 0
            ) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    summary: Dict = {}
    r, summary["sim"] = run_zoo_sim(fast)
    rows += r
    r, summary["serving"] = run_zoo_serving(fast, seed)
    rows += r
    r, summary["search"] = run_zoo_search()
    rows += r
    r, summary["collisions"] = run_collisions()
    rows += r
    summary["verdict"] = build_verdict(summary["sim"])
    for m, v in summary["verdict"].items():
        rows.append((f"zoo_verdict_{m}", 0.0, v["verdict"]))
    return rows, summary


def failed_checks(summary: Dict) -> List[str]:
    """``phase.check`` names of the failed boolean gates — shared by
    this CLI and ``run.py --zoo`` so both exit nonzero."""
    out = []
    for phase, sec in summary.items():
        if not isinstance(sec, dict):
            continue
        for name, v in sec.get("checks", {}).items():
            if isinstance(v, bool) and not v:
                out.append(f"{phase}.{name}")
    return out


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the zoo section to BENCH_sim.json without clobbering the
    figures/sweeps/real_traces/serving/search sections already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the zoo section only",
                  file=sys.stderr)
    data["zoo"] = summary
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset windows (CI wall clock)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, summary = run_all(fast=fast, seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# merged zoo section into {path}")

    failed = failed_checks(summary)
    if failed:
        print(f"# ZOO CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
