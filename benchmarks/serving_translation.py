"""Translation-costed serving benchmark: the paper's end-to-end claim.

NDPage's headline numbers are APPLICATION-level (14.3% / 9.8% / 30.5%
throughput at 1/4/8 cores), not just PTW latency.  This driver closes
the same loop at the serving layer: it replays two request mixes
through the paged-KV ``ServeEngine`` with a
:class:`repro.sim.cost_model.TranslationCostModel` attached, so every
scheduler-level translation (TranslationCache hit or table-walk miss,
with the rebuilt row's touched-PTE-line counts) is priced under ALL
mechanisms at once, and reports tokens/sec per mechanism.

Request mixes:

  * ``decode_heavy``  — short prompts, long generations: mappings grow
    page by page, versions churn, the translation cache misses often
    (the walk-dominated regime).
  * ``prefill_heavy`` — long prompts, short generations: mappings are
    built at admission and mostly stable (the TLB-hit regime).

One decode loop serves every mechanism — mechanism identity never
enters the jit, so NOTHING recompiles per mechanism; the only
simulator work is the one-shot cost-table derivation (one compile per
machine shape, memoized to ``.trace_cache/``; ``--pinned`` skips even
that and uses the committed table, which is what the CI fast lane
runs).

The ``"serving"`` section lands in ``BENCH_sim.json`` (merged into the
existing file, never clobbering the figures/sweeps/real_traces
sections).  Structural checks fail the run: under BOTH mixes, ndpage
tokens/sec >= radix and ideal is the upper bound.

Usage:
  python benchmarks/serving_translation.py [--smoke] [--pinned]
      [--seed N]
  python benchmarks/run.py --serving          # same, as a stage
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Tuple

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

Row = Tuple[str, float, str]

#: request mixes: (requests, prompt-length range, new tokens).  The
#: smoke variant trims counts, not structure — same regimes, CI cost.
MIXES: Dict[str, dict] = {
    "decode_heavy": dict(n_requests=8, prompt=(3, 8), new_tokens=16),
    "prefill_heavy": dict(n_requests=6, prompt=(24, 40), new_tokens=4),
}
SMOKE_MIXES: Dict[str, dict] = {
    "decode_heavy": dict(n_requests=4, prompt=(3, 8), new_tokens=8),
    "prefill_heavy": dict(n_requests=3, prompt=(24, 40), new_tokens=3),
}


def _engine_factory():
    """One tiny model + params shared by every mix (compile once)."""
    import jax

    from repro.config import get_arch, smoke_variant
    from repro.models import init_params
    cfg = dataclasses.replace(smoke_variant(get_arch("internlm2-1.8b")),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def run_serving(fast: bool = True, pinned: bool = False, seed: int = 0,
                source: str | None = None) -> Tuple[List[Row], Dict]:
    """``source`` overrides the cost-model source; default "pinned"
    when ``pinned`` else "auto" (memo -> sweep -> pinned fallback).
    Nightly passes "sweep" so a broken derivation fails the stage
    instead of silently serving the committed table."""
    from repro.serving import Request, ServeEngine
    from repro.sim.cost_model import TranslationCostModel
    from repro.sim.simulator import runner_cache_info

    info0 = runner_cache_info()
    t0 = time.perf_counter()
    model = TranslationCostModel.for_machine(
        source=source or ("pinned" if pinned else "auto"))
    cost_wall = time.perf_counter() - t0
    cost_compiles = runner_cache_info().misses - info0.misses

    cfg, params = _engine_factory()
    mixes = SMOKE_MIXES if fast else MIXES
    rows: List[Row] = []
    summary: Dict = {
        "seed": seed,
        "cost_model": {
            "source": model.source, "machine": model.machine,
            "mechs": list(model.mechs),
            "model_cycles_per_token": model.model_cycles_per_token,
            "runner_compiles": cost_compiles,
            "wall_s": round(cost_wall, 2),
        },
        "mixes": {},
    }
    import numpy as np
    for mi, (mix_name, mix) in enumerate(mixes.items()):
        rng = np.random.default_rng(seed * 1000 + mi)
        eng = ServeEngine(cfg, params, max_batch=4, max_len=96,
                          page_size=8, cost_model=model)
        t0 = time.perf_counter()
        for i in range(mix["n_requests"]):
            lo, hi = mix["prompt"]
            prompt = rng.integers(1, cfg.vocab_size,
                                  rng.integers(lo, hi)).astype(np.int32)
            eng.submit(Request(req_id=i, prompt=prompt,
                               max_new_tokens=mix["new_tokens"]))
        done = eng.run()
        wall = time.perf_counter() - t0
        rep = eng.throughput()
        tps = rep["tokens_per_sec"]
        checks = {
            "ndpage_ge_radix": tps["ndpage"] >= tps["radix"],
            "ideal_upper_bound": all(tps["ideal"] >= v - 1e-9
                                     for v in tps.values()),
            "all_completed": len(done) == mix["n_requests"],
        }
        for m in model.mechs:
            rows.append((f"serving_{mix_name}_{m}", 0.0,
                         f"{tps[m]:.0f} tok/s "
                         f"trans={rep['translation_cycles'][m]:.0f}cyc"))
        ok = all(checks.values())
        rows.append((f"serving_{mix_name}_check", wall * 1e6,
                     f"{'OK' if ok else 'FAIL'} {checks} "
                     f"hits={rep['tcache_hits']} "
                     f"misses={rep['tcache_misses']}"))
        summary["mixes"][mix_name] = {
            "requests": mix["n_requests"],
            "tokens": rep["tokens"], "steps": rep["steps"],
            "tcache_hits": rep["tcache_hits"],
            "tcache_misses": rep["tcache_misses"],
            "tokens_per_sec": {m: round(v, 1) for m, v in tps.items()},
            "translation_cycles": {
                m: round(v, 1)
                for m, v in rep["translation_cycles"].items()},
            "per_step_cycles": {
                m: {k: round(v, 1) for k, v in d.items()}
                for m, d in rep["per_step_cycles"].items()},
            "ndpage_speedup": round(tps["ndpage"] / tps["radix"], 4),
            "checks": checks,
            "wall_s": round(wall, 2),
        }
    return rows, summary


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the serving table to BENCH_sim.json without clobbering the
    figure-suite / sweeps / real_traces sections already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the serving section only",
                  file=sys.stderr)
    data["serving"] = summary
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def failed_checks(summary: Dict) -> List[str]:
    """Mix names whose structural checks failed — shared by this CLI
    and run.py --serving so both exit nonzero."""
    return [n for n, s in summary["mixes"].items()
            if not all(s["checks"].values())]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny request mixes (PR fast-lane cost)")
    p.add_argument("--pinned", action="store_true",
                   help="use the committed cost table — no simulator "
                        "run at all (hermetic)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, summary = run_serving(fast=args.smoke, pinned=args.pinned,
                                seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# wrote serving section into {path}")

    failed = failed_checks(summary)
    if failed:
        print(f"# SERVING CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
