"""Memory-model comparison: bounded_linear vs banked DRAM, end to end.

The banked row-buffer model (``repro.sim.memory_model``) is what prices
the paper's STRUCTURAL claim: a flattened table's leaf span is one
contiguous run of PTE lines, so a walk streams through open DRAM rows,
while radix per-node allocations land on scattered rows and keep paying
precharge+activate.  This driver re-runs the two sensitivity studies
that claim rides on — the L1-bypass ablation and the flattened-level
choice — under BOTH memory models and records whether

  * the bypass margin (ndpage over ndpage_nobyp, suite mean) widens
    when DRAM is banked, and
  * the flat-vs-radix per-PTE-line cost gap (the serving cost model's
    ``pte_line``) grows,

with an explicit VERDICT string, merged into ``BENCH_sim.json`` under a
``"memory_model"`` section (merge-not-clobber, like every other
section).

Dispatch shape: each grid is ONE bucketed sweep — ``memory_model`` is a
SHAPE axis (bank geometry is compiled in) and everything else rides the
batch lanes, so the whole 2-model x 2-mechs x W-workload grid costs one
``simulate_batch_varied`` dispatch per (machine-shape, walk-fn) bucket.
The driver runs at a chunk size no other stage uses, so the runner
cache is cold and ``compile count == new bucket count`` is ASSERTED,
not just reported.

Usage:  python benchmarks/sim_memory.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import OrderedDict
from typing import Dict, List, Tuple

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

Row = Tuple[str, float, str]

#: the two memory models every grid crosses
MODELS = ("bounded_linear", "banked")


def _grid(mech_pairs) -> "OrderedDict[str, Tuple]":
    from repro.configs.ndp_sim import SWEEP_WORKLOADS
    return OrderedDict([("memory_model", MODELS),
                        ("mechs", mech_pairs),
                        ("workload", SWEEP_WORKLOADS)])


def _mean_speedup(r, model: str, mechs: Tuple[str, ...], mech: str):
    """(workload,) speedup-over-radix array for one (model, mechs) row."""
    return r.select(memory_model=model, mechs=mechs).map(
        lambda x: x.speedup_vs()[mech])


class _CompileLedger:
    """Tracks (shape, walk-fn) bucket keys across the driver's sweep
    calls and asserts each call compiled EXACTLY its unseen buckets —
    the one-dispatch-per-bucket property as a hard gate, robust to the
    second grid legitimately reusing the first grid's shapes."""

    def __init__(self):
        self.seen: set = set()
        self.ok = True
        self.detail: List[str] = []

    def check(self, name: str, stats: Dict) -> None:
        new = 0
        for b in stats["per_bucket"]:
            key = (b["shape"], tuple(b["walk_fns"]))
            if key not in self.seen:
                self.seen.add(key)
                new += 1
        got = stats["runner_compiles"]
        self.ok = self.ok and (got == new)
        self.detail.append(f"{name}: {got} compiles for {new} new of "
                           f"{stats['buckets']} buckets")
        assert got == new, (
            f"{name}: expected one compile per new (shape, walk-fn) "
            f"bucket ({new}), runner cache reports {got}")


def _line_cost_gap() -> Dict:
    """The serving cost model's flat-vs-radix ``pte_line`` gap under
    each memory model: derive :class:`TranslationCostModel` on the SAME
    ndp serving machine with bounded vs banked DRAM and compare what a
    radix node line costs vs a flat-row line (positive gap = the flat
    organization's extra lines are cheaper)."""
    from repro.configs.ndp_sim import ndp_machine
    from repro.sim import apply_param
    from repro.sim.cost_model import TranslationCostModel
    out: Dict = {}
    for model in MODELS:
        mach = apply_param(ndp_machine(4), "memory_model", model)
        cm = TranslationCostModel.from_sim(mach)
        radix, flat = cm.cost("radix"), cm.cost("ndpage")
        out[model] = {
            "pte_line_radix": radix.pte_line,
            "pte_line_flat": flat.pte_line,
            "gap": round(radix.pte_line - flat.pte_line, 3),
            "dram_line_contiguous": mach.memory.line_cycles(True),
            "dram_line_scattered": mach.memory.line_cycles(False),
        }
    out["gap_grows"] = bool(out["banked"]["gap"]
                            > out["bounded_linear"]["gap"])
    return out


def run_memory_model(fast: bool) -> Tuple[List[Row], Dict]:
    from repro.configs.ndp_sim import PRESETS
    from repro.sim import sweep

    sim_preset = PRESETS["smoke" if fast else "full"]
    # a chunk no other benchmark stage uses: every bucket's runner is a
    # cold cache entry, so the compile==bucket assertion is meaningful
    chunk = sim_preset.chunk + 64
    ledger = _CompileLedger()
    rows: List[Row] = []
    section: Dict = {"preset": sim_preset.name, "chunk": chunk}

    t0 = time.perf_counter()
    bypass = sweep(_grid((("radix", "ndpage", "ideal"),
                          ("radix", "ndpage_nobyp", "ideal"))),
                   base="ndp", cores=4, preset=sim_preset.name,
                   chunk=chunk)
    ledger.check("bypass", bypass.stats)
    m_on, m_off = bypass.axes["mechs"]
    margins: Dict[str, Dict] = {}
    for model in MODELS:
        on = _mean_speedup(bypass, model, m_on, "ndpage")
        off = _mean_speedup(bypass, model, m_off, "ndpage_nobyp")
        margins[model] = {
            "mean_on": round(float(on.mean()), 4),
            "mean_off": round(float(off.mean()), 4),
            "margin": round(float(on.mean() - off.mean()), 4),
        }
        rows.append((f"memmodel_bypass_{model}", 0.0,
                     f"bypass_on={on.mean():.3f} "
                     f"bypass_off={off.mean():.3f} "
                     f"margin={on.mean() - off.mean():+.4f}"))
    margin_widens = bool(margins["banked"]["margin"]
                         > margins["bounded_linear"]["margin"])
    section["bypass"] = dict(margins, margin_widens=margin_widens)

    flatten = sweep(_grid((("radix", "ndpage", "ideal"),
                           ("radix", "ndpage_pl3", "ideal"))),
                    base="ndp", cores=4, preset=sim_preset.name,
                    chunk=chunk)
    ledger.check("flatten", flatten.stats)
    m_pl2, m_pl3 = flatten.axes["mechs"]
    flat_sec: Dict[str, Dict] = {}
    for model in MODELS:
        pl2 = _mean_speedup(flatten, model, m_pl2, "ndpage")
        pl3 = _mean_speedup(flatten, model, m_pl3, "ndpage_pl3")
        flat_sec[model] = {"mean_pl2": round(float(pl2.mean()), 4),
                           "mean_pl3": round(float(pl3.mean()), 4)}
        rows.append((f"memmodel_flatten_{model}", 0.0,
                     f"pl2={pl2.mean():.3f} pl3={pl3.mean():.3f}"))
    section["flatten"] = flat_sec

    gap = _line_cost_gap()
    section["line_cost"] = gap
    rows.append(("memmodel_line_cost", 0.0,
                 f"flat-vs-radix pte_line gap "
                 f"bounded={gap['bounded_linear']['gap']:+.1f} "
                 f"banked={gap['banked']['gap']:+.1f}"))

    wall = time.perf_counter() - t0
    verdict = (
        f"banked DRAM {'WIDENS' if margin_widens else 'does NOT widen'} "
        f"the L1-bypass margin "
        f"({margins['bounded_linear']['margin']:+.4f} -> "
        f"{margins['banked']['margin']:+.4f}) and the flat-vs-radix "
        f"line-cost gap {'GROWS' if gap['gap_grows'] else 'SHRINKS'} "
        f"({gap['bounded_linear']['gap']:+.1f} -> "
        f"{gap['banked']['gap']:+.1f} cycles/line): row-buffer locality "
        f"{'SUPPORTS' if gap['gap_grows'] else 'does not support'} the "
        f"flattened-table organization")
    section.update(
        verdict=verdict,
        checks={"compiles_match_new_buckets": ledger.ok,
                "line_cost_gap_grows": gap["gap_grows"]},
        compile_accounting=ledger.detail,
        wall_s=round(wall, 2))
    rows.append(("memmodel_verdict", 0.0, verdict))
    rows.append(("memmodel_engine",
                 wall * 1e6 / (bypass.stats["points"]
                               + flatten.stats["points"]),
                 f"{bypass.stats['points'] + flatten.stats['points']}pts "
                 f"{ledger.detail} {wall:.1f}s"))
    return rows, section


def merge_into_bench_json(section: Dict, path: str) -> None:
    """Attach the ``memory_model`` section without clobbering the
    figures/sweeps/serving/search sections already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the memory_model section only",
                  file=sys.stderr)
    data["memory_model"] = section
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def failed_checks(section: Dict) -> List[str]:
    return [n for n, v in section.get("checks", {}).items() if not v]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset windows (CI wall clock)")
    args = p.parse_args(argv)
    fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, section = run_memory_model(fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(section, path)
    print(f"# merged 'memory_model' section into {path}")

    failed = failed_checks(section)
    if failed:
        print(f"# MEMORY-MODEL CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
