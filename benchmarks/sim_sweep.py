"""Sensitivity-figure driver: the paper's design-space sweeps.

Runs the named sweep presets from ``repro.configs.ndp_sim.SWEEPS``
(PWC sizing, L1-DTLB sizing, L1-bypass ablation, flattened-level
choice, core scaling, memory latency) through the sweep engine — one
batched chunked-scan dispatch per compiled-shape bucket — prints
``name,us_per_call,derived`` CSV rows like the figure benchmarks, and
verifies the paper's sensitivity orderings:

  * NDPage >= radix at every PWC size and every TLB size,
  * bypass-off NDPage degrades toward radix (suite mean; stays >= 1),
  * translation overhead grows with core count.

The ``sweeps`` section written into ``BENCH_sim.json`` (merged into the
existing file when present) records, per preset, the point/bucket
counts, PER-BUCKET COMPILE COUNTS from the runner cache, and wall
clock — the "one compile per shape" property is part of the perf
trajectory future PRs compare against.

Usage:
  python benchmarks/sim_sweep.py [--fast] [--presets pwc_size,...]
``--fast`` (or SIM_FIGS_FAST=1) uses the smoke SimPreset windows; the
default uses the paper-figure ``full`` preset.  Set SIM_DEVICES=N to
shard each bucket's batch axis across N XLA host devices.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

#: preset -> ordering checks run on its result (name, fn(result) -> bool)
Row = Tuple[str, float, str]


def _speed(r, sel, mech):
    return r.select(mechs=sel).map(lambda x: x.speedup_vs()[mech])


def _rows_axis_sweep(name: str, r, axis: str) -> Tuple[List[Row], Dict]:
    """Rows + checks for sweeps with a (numeric axis x workload) grid and
    the full DEFAULT_MECHS tuple per point."""
    rows: List[Row] = []
    sp = r.speedup("ndpage")                       # (axis, workload)
    for i, v in enumerate(r.axes[axis]):
        per_wl = " ".join(f"{w}={sp[i, j]:.3f}"
                          for j, w in enumerate(r.axes["workload"]))
        rows.append((f"sweep_{name}_{v}", 0.0,
                     f"ndpage_speedup mean={sp[i].mean():.3f} {per_wl}"))
    ok = bool((sp >= 1.0).all())
    rows.append((f"sweep_{name}_check", 0.0,
                 f"ndpage>=radix at every {axis}: {'OK' if ok else 'FAIL'}"
                 f" (min={sp.min():.3f})"))
    return rows, {"ndpage_ge_radix_everywhere": ok,
                  "min_ndpage_speedup": round(float(sp.min()), 4),
                  "mean_by_" + axis: {
                      str(v): round(float(sp[i].mean()), 4)
                      for i, v in enumerate(r.axes[axis])}}


#: bypass-off may beat bypass-on per workload by at most this much
#: (cache-pollution noise on short smoke traces; the suite MEAN must
#: still order correctly) — the bounded-linear margin is thin by
#: construction, the structural widening is checked under the banked
#: model by benchmarks/sim_memory.py
_BYPASS_WL_TOL = 0.02


def _rows_bypass(name: str, r) -> Tuple[List[Row], Dict]:
    m_on, m_off = r.axes["mechs"]
    on = _speed(r, m_on, "ndpage")
    off = _speed(r, m_off, "ndpage_nobyp")
    rows = [(f"sweep_{name}_{w}", 0.0,
             f"bypass_on={on[j]:.3f} bypass_off={off[j]:.3f}")
            for j, w in enumerate(r.axes["workload"])]
    ok = (bool(off.mean() < on.mean()) and bool((off >= 1.0).all())
          and bool((off <= on + _BYPASS_WL_TOL).all()))
    rows.append((f"sweep_{name}_check", 0.0,
                 f"bypass-off degrades toward radix (mean "
                 f"{on.mean():.3f}->{off.mean():.3f}, stays >=1, "
                 f"per-workload within tol): {'OK' if ok else 'FAIL'}"))
    return rows, {"bypass_off_degrades": ok,
                  "mean_on": round(float(on.mean()), 4),
                  "mean_off": round(float(off.mean()), 4),
                  "max_wl_inversion": round(float((off - on).max()), 4)}


def _rows_flatten(name: str, r) -> Tuple[List[Row], Dict]:
    m_pl2, m_pl3 = r.axes["mechs"]
    pl2 = _speed(r, m_pl2, "ndpage")
    pl3 = _speed(r, m_pl3, "ndpage_pl3")
    rows = [(f"sweep_{name}_{w}", 0.0,
             f"pl2={pl2[j]:.3f} pl3={pl3[j]:.3f}")
            for j, w in enumerate(r.axes["workload"])]
    ok = bool((pl2 >= 1).all() and (pl3 >= 1).all())
    rows.append((f"sweep_{name}_check", 0.0,
                 f"both flattenings beat radix: {'OK' if ok else 'FAIL'}"))
    return rows, {"both_flattenings_beat_radix": ok,
                  "mean_pl2": round(float(pl2.mean()), 4),
                  "mean_pl3": round(float(pl3.mean()), 4)}


def _rows_cores(name: str, r) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    ptw = r.scalar("avg_ptw_latency", "radix").mean(axis=1)   # (cores,)
    sp = r.speedup("ndpage").mean(axis=1)
    hp = r.map(lambda x: x.speedup_vs()["hugepage"]).mean(axis=1)
    for i, c in enumerate(r.axes["cores"]):
        rows.append((f"sweep_{name}_{c}c", 0.0,
                     f"radix_ptw={ptw[i]:.1f}cyc "
                     f"ndpage_speedup={sp[i]:.3f} "
                     f"hugepage_speedup={hp[i]:.3f}"))
    # Fig 6: walk latency grows with cores (queueing); Fig 12 vs 14:
    # huge pages win at 1 core, collapse below radix by 8 (fragmentation)
    ok = bool((np.diff(ptw) > 0).all()) and bool(hp[0] > 1.0 > hp[-1])
    rows.append((f"sweep_{name}_check", 0.0,
                 "ptw grows with cores + hugepage collapse by 8c: "
                 f"{'OK' if ok else 'FAIL'}"))
    return rows, {"scaling_effects": ok,
                  "radix_ptw_by_cores": {
                      str(c): round(float(ptw[i]), 1)
                      for i, c in enumerate(r.axes["cores"])}}


def _rows_zoo(name: str, r) -> Tuple[List[Row], Dict]:
    """Related-work zoo on the zoo machine: per-workload speedups for
    every mechanism, plus ordering checks loose enough to survive trace
    regeneration but tight enough to catch a broken model (ideal is an
    upper bound; Victima's serial ctlb probe costs bounded overhead)."""
    mech_names = [m for m in r.results.flat[0].mechs if m != "radix"]
    sp = {m: r.map(lambda x, m=m: x.speedup_vs()[m]) for m in mech_names}
    rows: List[Row] = []
    for j, w in enumerate(r.axes["workload"]):
        per = " ".join(f"{m}={sp[m][..., j].mean():.3f}"
                       for m in mech_names)
        rows.append((f"sweep_{name}_{w}", 0.0, per))
    ideal = sp["ideal"]
    ok_ideal = bool(all((ideal >= sp[m] - 1e-6).all()
                        for m in mech_names))
    ok_victima = bool((sp["victima"] >= 0.9).all()) \
        if "victima" in sp else True
    checks = {"ideal_is_upper_bound": ok_ideal,
              "victima_probe_overhead_bounded": ok_victima}
    if "ndpage_search" in sp:
        checks["ndpage_search_beats_radix"] = \
            bool((sp["ndpage_search"] >= 1.0).all())
    rows.append((f"sweep_{name}_check", 0.0,
                 f"ideal upper bound + bounded victima overhead: "
                 f"{'OK' if all(v for v in checks.values()) else 'FAIL'}"))
    return rows, checks


def _rows_victima_reach(name: str, r) -> Tuple[List[Row], Dict]:
    """ctlb_kb reach sensitivity: victima must stay within
    [0.9, ideal] at every reach — the probe overhead is bounded and the
    cache-as-TLB can't beat perfect translation.  NO monotonicity check:
    set-associative LRU reach is not monotone on every trace."""
    v = r.map(lambda x: x.speedup_vs()["victima"])    # (ctlb_kb, wl)
    ideal = r.map(lambda x: x.speedup_vs()["ideal"])
    rows = [(f"sweep_{name}_{kb}kb", 0.0,
             "victima " + " ".join(
                 f"{w}={v[i, j]:.3f}"
                 for j, w in enumerate(r.axes["workload"])))
            for i, kb in enumerate(r.axes["ctlb_kb"])]
    ok = bool((v >= 0.9).all()) and bool((v <= ideal + 1e-6).all())
    rows.append((f"sweep_{name}_check", 0.0,
                 f"victima within [0.9, ideal] at every reach: "
                 f"{'OK' if ok else 'FAIL'} (min={v.min():.3f})"))
    return rows, {"victima_bounded_everywhere": ok,
                  "mean_by_ctlb_kb": {
                      str(kb): round(float(v[i].mean()), 4)
                      for i, kb in enumerate(r.axes["ctlb_kb"])}}


def _rows_banked(name: str, r) -> Tuple[List[Row], Dict]:
    """Banked-DRAM timing sensitivity: every point runs the banked
    memory model (memory_model x t_cas x t_rp x workload grid, one
    shape, one compile).  Checks: NDPage still beats radix at every
    timing point, and total cycles are monotone non-decreasing in
    ``t_cas`` (every DRAM access pays the column read, so a slower CAS
    can never speed the machine up)."""
    sp = r.speedup("ndpage")       # (model, t_cas, t_rp, workload)
    cyc = r.map(lambda x: float(x.cycles.mean()))
    t_cas = r.axes["memory.t_cas"]
    rows = [(f"sweep_{name}_tcas{v}", 0.0,
             f"ndpage_speedup mean={sp[:, i].mean():.3f} "
             f"cycles mean={cyc[:, i].mean():.0f}")
            for i, v in enumerate(t_cas)]
    ok_sp = bool((sp >= 1.0).all())
    ok_mono = bool((np.diff(cyc, axis=1) >= -1e-6).all())
    ok = ok_sp and ok_mono
    rows.append((f"sweep_{name}_check", 0.0,
                 f"ndpage>=radix everywhere + cycles monotone in t_cas: "
                 f"{'OK' if ok else 'FAIL'} (min={sp.min():.3f})"))
    return rows, {"ndpage_ge_radix_everywhere": ok_sp,
                  "cycles_monotone_in_t_cas": ok_mono,
                  "min_ndpage_speedup": round(float(sp.min()), 4),
                  "mean_by_t_cas": {
                      str(v): round(float(sp[:, i].mean()), 4)
                      for i, v in enumerate(t_cas)}}


_HANDLERS = {
    "pwc_size": lambda n, r: _rows_axis_sweep(n, r, "pwc_entries"),
    "tlb_size": lambda n, r: _rows_axis_sweep(n, r, "l1_dtlb.entries"),
    "mem_latency": lambda n, r: _rows_axis_sweep(n, r, "memory.latency"),
    "banked_timing": _rows_banked,
    "l1_bypass": _rows_bypass,
    "flatten_level": _rows_flatten,
    "core_scaling": _rows_cores,
    "zoo": _rows_zoo,
    "victima_reach": _rows_victima_reach,
}


def run_sweeps(presets: List[str], fast: bool) -> Tuple[List[Row], Dict]:
    from repro.configs.ndp_sim import PRESETS
    from repro.sim import sweep

    sim_preset = PRESETS["smoke" if fast else "full"]
    rows: List[Row] = []
    summary: Dict = {"preset": sim_preset.name, "sweeps": {}}
    for name in presets:
        t0 = time.perf_counter()
        r = sweep(name, preset=sim_preset.name)
        wall = time.perf_counter() - t0
        handler = _HANDLERS.get(name)
        checks: Dict = {}
        if handler is not None:
            srows, checks = handler(name, r)
            rows.extend(srows)
        rows.append((f"sweep_{name}_engine", wall * 1e6 / r.stats["points"],
                     f"{r.stats['points']}pts {r.stats['buckets']}buckets "
                     f"{r.stats['runner_compiles']}compiles "
                     f"{wall:.1f}s"))
        summary["sweeps"][name] = {
            "points": r.stats["points"],
            "buckets": r.stats["buckets"],
            "runner_compiles": r.stats["runner_compiles"],
            "compiles_per_bucket": [b["compiles"]
                                    for b in r.stats["per_bucket"]],
            "bucket_lanes": [b["lanes"] for b in r.stats["per_bucket"]],
            "wall_s": round(wall, 2),
            "checks": checks,
        }
    return rows, summary


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the sweep summary to BENCH_sim.json without clobbering the
    figure-suite perf numbers already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            # keep going (the sweep data is still worth writing) but
            # say so: the figure-suite perf section is being lost
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the sweeps section only",
                  file=sys.stderr)
    data["sweeps"] = summary["sweeps"]
    data["sweeps_preset"] = summary["preset"]
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset windows (CI wall clock)")
    p.add_argument("--presets", default=",".join(_HANDLERS),
                   help="comma-separated preset names (default: all)")
    args = p.parse_args(argv)
    fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))

    # same env plumbing as run.py: host-device sharding + XLA cache
    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    presets = [s for s in args.presets.split(",") if s]
    rows, summary = run_sweeps(presets, fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# wrote sweeps section into {path}")

    failed = failed_checks(summary)
    if failed:
        print(f"# ORDERING CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


def failed_checks(summary: Dict) -> List[str]:
    """Preset names whose ordering checks (the boolean entries) failed —
    shared by this CLI and run.py --sweeps so both exit nonzero."""
    return [n for n, s in summary["sweeps"].items()
            if not all(v for v in s["checks"].values()
                       if isinstance(v, bool))]


if __name__ == "__main__":
    sys.exit(main())
