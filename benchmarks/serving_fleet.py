"""Fleet-scale costed serving benchmark: continuous batching at
thousands of live sequences, prefix sharing, translation-aware
admission.

Four runs of the :class:`repro.serving.FleetEngine` (one jitted
surrogate decode shared by ALL of them — the mechanism, the mix and the
sharing flag never enter the jit, so the whole benchmark compiles ONE
decode graph):

  * ``shared``       — a shared-prompt mix (``prefix_groups`` system
    prompts of ``prefix_len`` tokens + per-request tails) with prefix
    sharing ON: sharers map the fully-covered prefix pages to one
    refcounted allocation and the radix-org pricing dedups the shared
    leaves batch-globally.
  * ``unshared``     — the SAME mix with sharing OFF (the control):
    generated tokens must be bit-identical; only radix-family
    translation cycles may move.  The gap is the radix line-sharing
    win the flat (NDPage) org cannot have — and it shows up in
    tokens/sec.
  * ``independent``  — a no-prefix mix (nothing to share; baseline
    shape of the fleet numbers).
  * ``budget``       — the shared mix under a per-step translation
    cycle budget: admission prices each candidate under the budget
    mechanism and stops admitting when the estimated per-step spend
    would exceed it (plus sustained-overshoot preemption), so peak
    concurrency is set by TRANSLATION cost, not page supply.

The ``shared`` run's accumulated translation cycles are then re-priced
under a ``model_cycles_per_token`` grid (same totals, no re-run) to map
where translation stops mattering for end-to-end tokens/sec.

Structural gates (run fails nonzero): peak concurrency reaches the
fleet target, one decode trace, bit-exact tokens sharing on/off, ndpage
>= radix and ideal the upper bound everywhere, radix (not ndpage) gains
from sharing in cycles AND tokens/sec, the budget run peaks strictly
below the unbudgeted run, the meter's per-request budgets partition its
totals, and the mcpt speedup curve is monotone.

The ``"serving_fleet"`` section lands in ``BENCH_sim.json`` (merged,
never clobbering the other sections).

Usage:
  python benchmarks/serving_fleet.py [--smoke] [--pinned] [--seed N]
  python benchmarks/run.py --serving-fleet       # same, as a stage
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

Row = Tuple[str, float, str]


def _fleet_params(fast: bool) -> Dict:
    from repro.configs.ndp_sim import SERVING_FLEET
    p = {k: v for k, v in SERVING_FLEET.items() if k != "smoke"}
    if fast:
        p.update(SERVING_FLEET["smoke"])
    return p


def _mix(p: Dict, seed: int, shared: bool):
    """The request list for one run — built fresh per run (requests are
    mutated by the scheduler) but identical across runs of the same
    seed/mix, so on/off comparisons are apples-to-apples."""
    import numpy as np

    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    if shared:
        prefixes = {g: rng.integers(1, 30_000, p["prefix_len"])
                    for g in range(p["prefix_groups"])}
        for i in range(p["num_requests"]):
            g = i % p["prefix_groups"]
            tail = rng.integers(1, 30_000, p["tail_tokens"])
            reqs.append(Request.build(
                i, np.concatenate([prefixes[g], tail]),
                max_new_tokens=p["new_tokens"],
                prefix_id=g, prefix_len=p["prefix_len"]))
    else:
        lo, hi = p["independent_prompt"]
        for i in range(p["num_requests"]):
            prompt = rng.integers(1, 30_000, rng.integers(lo, hi))
            reqs.append(Request.build(i, prompt,
                                      max_new_tokens=p["new_tokens"]))
    return reqs


def _run_one(p: Dict, model, reqs, *, prefix_sharing: bool,
             translation_budget=None) -> Tuple[Dict, object]:
    from repro.serving import FleetEngine
    eng = FleetEngine(
        max_batch=p["max_batch"], max_len=p["max_len"],
        page_size=p["page_size"], leaf_size=p["leaf_size"],
        cost_model=model, prefix_sharing=prefix_sharing,
        translation_budget=translation_budget,
        budget_mech=p["budget_mech"])
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    rep = eng.throughput()
    rep["finished"] = done
    rep["wall_s"] = round(wall, 2)
    return rep, eng


def _meter_conserved(eng) -> bool:
    """The meter's per-request budgets must partition its totals (all
    slots released, nothing double- or under-counted)."""
    import numpy as np
    budgets = eng.meter.request_budgets()
    if not budgets:
        return eng.meter.total.sum() == 0.0
    acc = np.sum(list(budgets.values()), axis=0)
    return bool(np.allclose(acc, eng.meter.total, rtol=1e-9, atol=1e-6))


def run_fleet(fast: bool = True, pinned: bool = False, seed: int = 0,
              source: str | None = None) -> Tuple[List[Row], Dict]:
    from repro.serving.fleet import decode_trace_count
    from repro.sim.cost_model import TranslationCostModel

    model = TranslationCostModel.for_machine(
        source=source or ("pinned" if pinned else "auto"))
    p = _fleet_params(fast)
    traces0 = decode_trace_count()

    runs: Dict[str, Dict] = {}
    engines: Dict[str, object] = {}
    runs["shared"], engines["shared"] = _run_one(
        p, model, _mix(p, seed, shared=True), prefix_sharing=True)
    runs["unshared"], engines["unshared"] = _run_one(
        p, model, _mix(p, seed, shared=True), prefix_sharing=False)
    runs["independent"], engines["independent"] = _run_one(
        p, model, _mix(p, seed + 1, shared=False), prefix_sharing=True)
    runs["budget"], engines["budget"] = _run_one(
        p, model, _mix(p, seed, shared=True), prefix_sharing=True,
        translation_budget=p["translation_budget"])
    trace_delta = decode_trace_count() - traces0

    # -- gates ---------------------------------------------------------------
    n = p["num_requests"]
    fleet_target = min(p["max_batch"], n)
    sh, un, bud = runs["shared"], runs["unshared"], runs["budget"]
    gen = {}
    for name, rep in runs.items():
        gen[name] = {r.req_id: list(r.generated) for r in rep["finished"]}
    tps_sh, tps_un = sh["tokens_per_sec"], un["tokens_per_sec"]
    cyc_sh, cyc_un = sh["translation_cycles"], un["translation_cycles"]
    bs = bud["stats"]

    checks = {
        # fleet scale: the batch actually fills, on one compiled graph
        "fleet_concurrency": all(
            runs[r]["peak_running"] >= fleet_target
            for r in ("shared", "unshared", "independent")),
        "one_decode_trace": trace_delta <= 1,
        "all_completed": all(
            len(gen[r]) == n for r in ("shared", "unshared",
                                       "independent")),
        # sharing is a pure translation-cost effect: tokens identical
        "tokens_exact_on_off": gen["shared"] == gen["unshared"],
        # the paper's ordering, under every run
        "ndpage_ge_radix": all(
            rep["tokens_per_sec"]["ndpage"]
            >= rep["tokens_per_sec"]["radix"] for rep in runs.values()),
        "ideal_upper_bound": all(
            rep["tokens_per_sec"]["ideal"] >= v - 1e-9
            for rep in runs.values()
            for v in rep["tokens_per_sec"].values()),
        # the radix line-sharing win: cycles drop AND tokens/sec move,
        # while the flat org (per-sequence contiguous rows) is immune
        "radix_gains_cycles": cyc_sh["radix"] < cyc_un["radix"],
        "radix_gains_tps": tps_sh["radix"] > tps_un["radix"],
        "flat_immune": cyc_sh["ndpage"] == cyc_un["ndpage"],
        "sharing_gap_radix_over_flat": (
            (tps_sh["radix"] / tps_un["radix"])
            > (tps_sh["ndpage"] / tps_un["ndpage"])),
        # translation-aware admission binds concurrency
        "budget_caps_concurrency":
            bud["peak_running"] < sh["peak_running"],
        "budget_conserves_requests":
            bs["completed"] + bs["shed"] == n,
        # accounting: per-request budgets partition the meter totals
        "meter_conserved": all(_meter_conserved(engines[r])
                               for r in runs),
    }

    # -- mcpt sweep: reprice the SAME totals, no re-run ----------------------
    meter = engines["shared"].meter
    mcpt_rows = []
    for mcpt in p["mcpt_grid"]:
        tps = meter.tokens_per_sec(model_cycles_per_token=mcpt)
        mcpt_rows.append({
            "model_cycles_per_token": mcpt,
            "tokens_per_sec": {m: round(v, 1) for m, v in tps.items()},
            "ndpage_speedup": round(tps["ndpage"] / tps["radix"], 4),
        })
    speedups = [r["ndpage_speedup"] for r in mcpt_rows]
    checks["mcpt_speedup_monotone"] = all(
        a >= b - 1e-9 for a, b in zip(speedups, speedups[1:]))
    checks["translation_matters_at_low_mcpt"] = (
        speedups[0] > speedups[-1])

    # -- report --------------------------------------------------------------
    rows: List[Row] = []
    summary: Dict = {
        "seed": seed,
        "params": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in p.items()},
        "cost_model": {"source": model.source, "machine": model.machine,
                       "mechs": list(model.mechs),
                       "model_cycles_per_token":
                           model.model_cycles_per_token},
        "decode_trace_delta": trace_delta,
        "runs": {}, "mcpt_sweep": mcpt_rows, "checks": checks,
    }
    for name, rep in runs.items():
        stats = rep["stats"]
        summary["runs"][name] = {
            "requests": n, "completed": stats["completed"],
            "shed": stats["shed"], "preempted": stats["preempted"],
            "peak_running": rep["peak_running"],
            "steps": rep["steps"], "tokens": rep["tokens"],
            "tcache_hits": rep["tcache_hits"],
            "tcache_misses": rep["tcache_misses"],
            "occupancy_modes": {
                "flat": stats["mode_flat_steps"],
                "radix": stats["mode_radix_steps"]},
            "tokens_per_sec": {m: round(v, 1)
                               for m, v in rep["tokens_per_sec"].items()},
            "translation_cycles": {
                m: round(v, 1)
                for m, v in rep["translation_cycles"].items()},
            "ndpage_speedup": round(rep["tokens_per_sec"]["ndpage"]
                                    / rep["tokens_per_sec"]["radix"], 4),
            "wall_s": rep["wall_s"],
        }
        for m in model.mechs:
            rows.append((f"fleet_{name}_{m}", 0.0,
                         f"{rep['tokens_per_sec'][m]:.0f} tok/s "
                         f"trans={rep['translation_cycles'][m]:.0f}cyc"))
        rows.append((f"fleet_{name}", rep["wall_s"] * 1e6,
                     f"peak={rep['peak_running']} steps={rep['steps']} "
                     f"completed={stats['completed']}/{n}"))
    sharing_gap = tps_sh["radix"] / tps_un["radix"]
    rows.append(("fleet_sharing_gap_radix", 0.0,
                 f"{(sharing_gap - 1) * 100:.2f}% tok/s from prefix "
                 f"sharing (flat: "
                 f"{(tps_sh['ndpage'] / tps_un['ndpage'] - 1) * 100:.2f}%)"))
    ok = all(checks.values())
    rows.append(("fleet_checks", 0.0,
                 f"{'OK' if ok else 'FAIL'} "
                 f"{[k for k, v in checks.items() if not v]}"))
    summary["sharing_gap_radix"] = round(sharing_gap, 4)
    return rows, summary


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the fleet table to BENCH_sim.json without clobbering the
    other sections already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the serving_fleet section only",
                  file=sys.stderr)
    data["serving_fleet"] = summary
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def failed_checks(summary: Dict) -> List[str]:
    """Names of failed structural gates — shared by this CLI and
    run.py --serving-fleet so both exit nonzero."""
    return [k for k, v in summary["checks"].items() if not v]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="trimmed fleet (PR fast-lane cost; same "
                        "structure, smaller counts)")
    p.add_argument("--pinned", action="store_true",
                   help="use the committed cost table — no simulator "
                        "run at all (hermetic)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, summary = run_fleet(fast=args.smoke, pinned=args.pinned,
                              seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# wrote serving_fleet section into {path}")

    failed = failed_checks(summary)
    if failed:
        print(f"# FLEET CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
