"""Design-space search driver + frontier-regression gate.

Runs the seeded evolutionary search from :mod:`repro.sim._search` over a
named space (``repro.configs.ndp_sim.SEARCH_SPACES``), prints
``name,us_per_call,derived`` CSV rows like the other benchmark drivers,
merges the ``"search"`` section into ``BENCH_sim.json`` (never
clobbering the figures/sweeps/real_traces/serving sections), and gates:

  * the Pareto frontier is non-empty and contains no dominated points,
  * the paper's NDPage config was evaluated and carries an explicit
    dominates-paper verdict,
  * compile count stayed within the (machine-shape x walk-fn) bucket
    bound — the sweep engine's no-recompile invariant held,
  * FRONTIER REGRESSION: every genome pinned in
    ``benchmarks/frontier_baseline.json`` is re-evaluated under the
    current engine and must still be non-dominated by anything this
    run discovered.  The pinned genomes are compared on FRESH objective
    values, so the gate is robust to float drift across jax versions
    but fires whenever a model change (or a search improvement) pushes
    a pinned point off the frontier — refresh deliberately with
    ``--update-baseline``.

Usage:
  python benchmarks/sim_search.py [--space default] [--seed N]
                                  [--no-cache] [--update-baseline]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BASELINE_PATH = os.path.join(_ROOT, "benchmarks", "frontier_baseline.json")

Row = Tuple[str, float, str]


def _baseline_genomes(space, baseline: Dict) -> List[Tuple]:
    """The pinned genomes as knob-ordered tuples (JSON lists become the
    tuples the search layer hashes on)."""
    out = []
    for pt in baseline.get("points", []):
        g = pt["genome"]
        out.append(tuple(
            tuple(g[n]) if isinstance(g[n], list) else g[n]
            for n in space.knob_names))
    return out


def check_frontier_baseline(result, path: str = BASELINE_PATH
                            ) -> Tuple[bool, str]:
    """True iff every pinned-frontier genome is still non-dominated.

    Pinned genomes absent from this run's candidate set are re-evaluated
    (one extra bucketed dispatch at most); dominance is then checked
    against everything the run discovered, on current-engine objective
    values.
    """
    from repro.sim._search import (dominates, evaluate_genomes,
                                  genome_key)
    if not os.path.exists(path):
        return True, "no baseline pinned (run --update-baseline)"
    try:
        with open(path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return False, f"unreadable baseline {path}: {e}"
    if baseline.get("space") != result.space.name:
        return True, (f"baseline pins space {baseline.get('space')!r}, "
                      f"run used {result.space.name!r} — skipped")
    pinned = _baseline_genomes(result.space, baseline)
    if not pinned:
        return False, "baseline has no pinned points"

    # seed the eval cache with everything the run already computed so
    # only genuinely-missing pinned genomes re-dispatch
    cache = {genome_key(result.space, tuple(c.genome.values())): {
        "objectives": c.objectives, "per_workload": c.per_workload,
        "mech": c.mech} for c in result.candidates}
    evals, _ = evaluate_genomes(result.space, pinned, cache=cache)

    field = [c.objectives for c in result.candidates]
    field += [obj for obj, _, _ in evals]
    regressed = []
    for g, (obj, _, _) in zip(pinned, evals):
        if any(dominates(other, obj) for other in field):
            regressed.append(f"{dict(zip(result.space.knob_names, g))} "
                             f"now dominated ({obj})")
    if regressed:
        return False, "; ".join(regressed)
    return True, f"all {len(pinned)} pinned points still non-dominated"


def update_baseline(result, path: str = BASELINE_PATH) -> None:
    """Pin the current frontier's genomes (objectives recorded for
    humans only — the gate always re-evaluates)."""
    data = {
        "space": result.space.name,
        "seed": result.provenance["seed"],
        "objectives": [{"name": n, "direction": d}
                       for n, d in result.objectives],
        "points": [c.to_json_dict() for c in result.frontier],
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def run_search(space: str = "default", *, seed: int | None = None,
               use_cache: bool = True,
               baseline_path: str = BASELINE_PATH
               ) -> Tuple[List[Row], Dict]:
    """Run the search + all gates.  Returns CSV rows and a summary dict
    whose ``"section"`` is the BENCH_sim.json payload and whose
    ``"checks"`` booleans feed :func:`failed_checks`."""
    from repro.sim._search import pareto_indices
    from repro.sim._search import search as run

    result = run(space, seed=seed, use_cache=use_cache)
    p = result.provenance

    rows: List[Row] = []
    for c in result.frontier:
        o = c.objectives
        rows.append((f"search_front_{c.mech}_{o['sram_kb']:g}KB", 0.0,
                     f"speedup={o['mean_speedup']:.4f} "
                     f"worst_ptw={o['worst_ptw']:.1f}cyc "
                     f"gen={c.gen} origin={c.origin}"))
    v = result.verdict
    rows.append(("search_verdict", 0.0,
                 f"paper config dominated: {v['dominates_paper']} "
                 f"({v['n_dominating']} dominating points)"))
    rows.append(("search_engine",
                 p["wall_s"] * 1e6 / max(p["evaluated"], 1),
                 f"{p['evaluated']}cands {p['lanes_dispatched']}lanes "
                 f"{p['distinct_buckets']}buckets "
                 f"{p['runner_compiles']}compiles {p['wall_s']:.1f}s"))

    refront = pareto_indices([c.objectives for c in result.frontier])
    baseline_ok, baseline_note = check_frontier_baseline(
        result, baseline_path)
    checks = {
        "frontier_nonempty": bool(result.frontier),
        "no_dominated_in_frontier":
            len(refront) == len(result.frontier),
        "paper_evaluated": result.paper.origin == "paper",
        "verdict_present": isinstance(v.get("dominates_paper"), bool),
        # warm persistent caches can only LOWER the compile count
        "compile_bound":
            p["runner_compiles"] <= p["distinct_buckets"],
        "frontier_baseline_ok": baseline_ok,
        "baseline_note": baseline_note,
    }
    rows.append(("search_frontier_gate", 0.0,
                 f"{'OK' if baseline_ok else 'FAIL'}: {baseline_note}"))

    section = result.to_json_dict()
    section["checks"] = checks
    return rows, {"section": section, "checks": checks,
                  "result": result}


def failed_checks(summary: Dict) -> List[str]:
    """Names of the failed boolean gates — shared by this CLI and
    ``run.py --search`` so both exit nonzero."""
    return [n for n, v in summary["checks"].items()
            if isinstance(v, bool) and not v]


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the search section to BENCH_sim.json without clobbering
    the figures/sweeps/real_traces/serving sections already there."""
    from repro.sim._search import merge_search_section
    merge_search_section(summary["section"], path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--space", default="default",
                    help="search space name (SEARCH_SPACES)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the space's pinned seed")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore the on-disk eval cache")
    ap.add_argument("--update-baseline", action="store_true",
                    help="pin the discovered frontier as the new "
                         "regression baseline")
    args = ap.parse_args(argv)

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, summary = run_search(args.space, seed=args.seed,
                               use_cache=not args.no_cache)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# merged search section into {path}")

    if args.update_baseline:
        update_baseline(summary["result"])
        print(f"# pinned frontier baseline -> {BASELINE_PATH}")
        # the just-pinned frontier is non-dominated by construction
        summary["checks"]["frontier_baseline_ok"] = True

    failed = failed_checks(summary)
    if failed:
        print(f"# SEARCH GATE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
