"""Reproduction benchmarks: one function per paper figure (Figs 4-8, 12-14).

Each returns a list of CSV rows (name, us_per_call, derived) where
``derived`` carries the figure's metric; a JSON blob with the full data is
written to bench_results.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.ndp_sim import (CORE_COUNTS, WORKLOADS, cpu_machine,
                                   ndp_machine)
from repro.core import page_table as PT
from repro.sim import simulate
from repro.workloads import generate_trace

TRACE_LEN = 8000
_CACHE: Dict[Tuple[str, str, int], object] = {}


def _sim(workload: str, machine: str, cores: int):
    key = (workload, machine, cores)
    if key not in _CACHE:
        mach = ndp_machine(cores) if machine == "ndp" else cpu_machine(cores)
        t0 = time.time()
        res = simulate(mach, generate_trace(workload, cores, TRACE_LEN))
        _CACHE[key] = (res, time.time() - t0)
    return _CACHE[key]


def fig4_ptw_latency() -> List[Tuple[str, float, str]]:
    """Avg PTW latency, 4-core NDP vs CPU (paper: 474.56 vs ~144, +229%)."""
    rows = []
    nd_all, cpu_all = [], []
    for w in WORKLOADS:
        nd, t1 = _sim(w, "ndp", 4)
        cp, t2 = _sim(w, "cpu", 4)
        nd_ptw = float(nd.avg_ptw_latency()[0])
        cp_ptw = float(cp.avg_ptw_latency()[0])
        nd_all.append(nd_ptw)
        cpu_all.append(cp_ptw)
        rows.append((f"fig4_ptw_{w}", (t1 + t2) * 1e6,
                     f"ndp={nd_ptw:.1f}cyc cpu={cp_ptw:.1f}cyc"))
    inc = (np.mean(nd_all) / np.mean(cpu_all) - 1) * 100
    rows.append(("fig4_ptw_avg", 0.0,
                 f"ndp={np.mean(nd_all):.1f} cpu={np.mean(cpu_all):.1f} "
                 f"increment={inc:.0f}% (paper: 474.56 / +229%)"))
    return rows


def fig5_translation_overhead() -> List[Tuple[str, float, str]]:
    """Fraction of execution spent translating, 4 cores (paper: 67.1% NDP
    vs 34.51% CPU)."""
    rows = []
    nd_all, cpu_all = [], []
    for w in WORKLOADS:
        nd, t1 = _sim(w, "ndp", 4)
        cp, t2 = _sim(w, "cpu", 4)
        ndf = float(nd.translation_fraction()[0])
        cpf = float(cp.translation_fraction()[0])
        nd_all.append(ndf)
        cpu_all.append(cpf)
        rows.append((f"fig5_overhead_{w}", (t1 + t2) * 1e6,
                     f"ndp={ndf:.3f} cpu={cpf:.3f}"))
    rows.append(("fig5_overhead_avg", 0.0,
                 f"ndp={np.mean(nd_all):.3f} cpu={np.mean(cpu_all):.3f} "
                 "(paper: 0.671 / 0.345)"))
    return rows


def fig6_core_scaling() -> List[Tuple[str, float, str]]:
    """PTW latency + overhead vs core count (paper NDP: 242.85 -> 551.83)."""
    rows = []
    for cores in CORE_COUNTS:
        for machine in ("ndp", "cpu"):
            ptws, tfs, us = [], [], 0.0
            for w in WORKLOADS:
                r, t = _sim(w, machine, cores)
                ptws.append(float(r.avg_ptw_latency()[0]))
                tfs.append(float(r.translation_fraction()[0]))
                us += t * 1e6
            rows.append((f"fig6_{machine}_{cores}c", us,
                         f"ptw={np.mean(ptws):.1f} "
                         f"overhead={np.mean(tfs):.3f}"))
    return rows


def fig7_miss_rates() -> List[Tuple[str, float, str]]:
    """L1 miss of PTEs vs data (radix) vs ideal data (paper: 98.28% PTE;
    35.89% vs 26.16% data)."""
    rows = []
    pte, dat, ideal = [], [], []
    for w in WORKLOADS:
        r, t = _sim(w, "ndp", 4)
        pte.append(float(r.pte_l1_miss_rate()[0]))
        dat.append(float(r.data_l1_miss_rate()[0]))
        ideal.append(float(r.data_l1_miss_rate()[4]))
        rows.append((f"fig7_miss_{w}", t * 1e6,
                     f"pte={pte[-1]:.3f} data={dat[-1]:.3f} "
                     f"ideal={ideal[-1]:.3f}"))
    rows.append(("fig7_miss_avg", 0.0,
                 f"pte={np.mean(pte):.3f} data={np.mean(dat):.3f} "
                 f"ideal={np.mean(ideal):.3f} "
                 "(paper: .983 / .359 / .262)"))
    return rows


def fig8_occupancy() -> List[Tuple[str, float, str]]:
    """Page-table occupancy per level (paper: PL2 98.24%, PL1 97.97%)."""
    rows = []
    occs = []
    for w in WORKLOADS:
        t0 = time.time()
        tr = generate_trace(w, 4, TRACE_LEN)
        # occupancy over the dataset's allocated footprint: data-intensive
        # kernels touch essentially all resident pages over the full run;
        # the touched-VPN set of the window under-samples, so evaluate on
        # the footprint range (what the OS has mapped).
        vpns = np.arange(0, tr["pages"], dtype=np.int64)
        l4, l3, l2, l1 = PT.occupancy_by_level(vpns)
        occs.append((l4, l3, l2, l1))
        rows.append((f"fig8_occ_{w}", (time.time() - t0) * 1e6,
                     f"PL4={l4:.4f} PL3={l3:.4f} PL2={l2:.3f} PL1={l1:.3f}"))
    m = np.mean(occs, axis=0)
    rows.append(("fig8_occ_avg", 0.0,
                 f"PL4={m[0]:.4f} PL3={m[1]:.4f} PL2={m[2]:.3f} "
                 f"PL1={m[3]:.3f} (paper: .0043/.0312/.9824/.9797)"))
    return rows


def _speedup_fig(cores: int, fig: str, paper: Dict[str, float]):
    rows = []
    sp = {m: [] for m in ("ech", "hugepage", "ndpage", "ideal")}
    for w in WORKLOADS:
        r, t = _sim(w, "ndp", cores)
        s = r.speedup_vs()
        for m in sp:
            sp[m].append(s[m])
        rows.append((f"{fig}_{w}", t * 1e6,
                     " ".join(f"{m}={s[m]:.3f}" for m in sp)))
    avg = {m: float(np.mean(v)) for m, v in sp.items()}
    rows.append((f"{fig}_avg", 0.0,
                 " ".join(f"{m}={avg[m]:.3f}" for m in sp)
                 + f" (paper: {paper})"))
    return rows, avg


def fig12_single_core():
    return _speedup_fig(1, "fig12_1c",
                        {"ech": 1.176, "hugepage": 1.08, "ndpage": 1.344})


def fig13_four_core():
    return _speedup_fig(4, "fig13_4c",
                        {"ech": 1.299, "ndpage": 1.426})


def fig14_eight_core():
    return _speedup_fig(8, "fig14_8c",
                        {"ech": 1.078, "hugepage": 0.901, "ndpage": 1.407})


ALL_FIGS = [fig4_ptw_latency, fig5_translation_overhead, fig6_core_scaling,
            fig7_miss_rates, fig8_occupancy]


def run_all() -> Tuple[List[Tuple[str, float, str]], Dict]:
    rows: List[Tuple[str, float, str]] = []
    summary: Dict = {}
    for fn in ALL_FIGS:
        rows.extend(fn())
    for fn, paper_nd in ((fig12_single_core, 1.344), (fig13_four_core, 1.426),
                         (fig14_eight_core, 1.407)):
        r, avg = fn()
        rows.extend(r)
        summary[fn.__name__] = {"ours": avg, "paper_ndpage": paper_nd}
    return rows, summary
