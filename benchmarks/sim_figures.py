"""Reproduction benchmarks: one function per paper figure (Figs 4-8, 12-14).

Each returns a list of CSV rows (name, us_per_call, derived) where
``derived`` carries the figure's metric; a JSON blob with the full data is
written to bench_results.json for EXPERIMENTS.md.

Two paths share all the code:

* default: the paper-figure configuration (8000-entry windows),
* fast (``SIM_FIGS_FAST=1`` or ``benchmarks/run.py --fast``): the
  ``smoke`` preset's short windows — same engine, same orderings, CI
  wall-clock.

``run_all`` groups the 66 (workload, machine, cores) simulations into
**batch buckets** — one per (machine, cores), all workloads stacked on
the engine's B axis — and runs each bucket as a single
``simulate_batch`` dispatch (sharded across host devices when
``SIM_DEVICES`` is set).  Per-stage wall clock (trace generation,
estimated compile, steady-state run) is accumulated for BENCH_sim.json.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.ndp_sim import (CORE_COUNTS, PRESETS, WORKLOADS,
                                   cpu_machine, ndp_machine)
from repro.core import page_table as PT
from repro.sim import simulate_batch
from repro.sim.mechanisms import DEFAULT_MECHS
from repro.workloads import generate_trace, generate_traces

FAST = bool(int(os.environ.get("SIM_FIGS_FAST", "0")))
PRESET = PRESETS["smoke" if FAST else "full"]
TRACE_LEN = PRESET.trace_len

#: (workload, machine, cores) -> (SimResult, per-sim wall seconds)
_CACHE: Dict[Tuple[str, str, int], Tuple[object, float]] = {}
#: accumulated per-stage wall clock across every bucket run
_STAGES = {"trace_gen_s": 0.0, "compile_s_est": 0.0, "run_s": 0.0}


def _machine(machine: str, cores: int):
    return ndp_machine(cores) if machine == "ndp" else cpu_machine(cores)


def _run_bucket(machine: str, cores: int) -> None:
    """One (machine, cores) bucket: every workload batched on the B axis
    through a single chunked-scan dispatch.  Memoized like _sim: a
    bucket already in _CACHE is not re-simulated (repeated run_all()
    calls in one process must not double-count _STAGES)."""
    workloads = list(WORKLOADS)
    if all((w, machine, cores) in _CACHE for w in workloads):
        return
    t0 = time.perf_counter()
    traces = generate_traces(workloads, cores, preset=PRESET)
    _STAGES["trace_gen_s"] += time.perf_counter() - t0

    tm: Dict = {}
    t0 = time.perf_counter()
    results = simulate_batch(_machine(machine, cores), traces,
                             chunk=PRESET.chunk, timings=tm)
    wall = time.perf_counter() - t0
    # stages are disjoint: run_s already excludes the compile estimate
    compile_est = tm.get("compile_s_est", 0.0)
    _STAGES["compile_s_est"] += compile_est
    _STAGES["run_s"] += tm.get("run_s", wall - compile_est)

    per_sim = wall / len(workloads)
    for w, res in zip(workloads, results):
        _CACHE[(w, machine, cores)] = (res, per_sim)


def _sim(workload: str, machine: str, cores: int):
    key = (workload, machine, cores)
    if key not in _CACHE:
        _run_bucket(machine, cores)      # fills every workload of the bucket
    return _CACHE[key]


def _all_combos() -> List[Tuple[str, str, int]]:
    combos = []
    for w in WORKLOADS:
        for cores in CORE_COUNTS:
            combos.append((w, "ndp", cores))
            combos.append((w, "cpu", cores))
    return combos


def _all_buckets() -> List[Tuple[str, int]]:
    """The batch grouping of :func:`_all_combos`: one bucket per
    (machine, cores), workloads riding the B axis."""
    return [(machine, cores) for cores in CORE_COUNTS
            for machine in ("ndp", "cpu")]


def prewarm() -> float:
    """Run every simulation the figures need, one batched dispatch per
    bucket.  Returns the wall-clock spent."""
    t0 = time.time()
    for machine, cores in _all_buckets():
        _run_bucket(machine, cores)
    return time.time() - t0


def fig4_ptw_latency() -> List[Tuple[str, float, str]]:
    """Avg PTW latency, 4-core NDP vs CPU (paper: 474.56 vs ~144, +229%)."""
    rows = []
    nd_all, cpu_all = [], []
    for w in WORKLOADS:
        nd, t1 = _sim(w, "ndp", 4)
        cp, t2 = _sim(w, "cpu", 4)
        nd_ptw = nd.scalar("avg_ptw_latency", "radix")
        cp_ptw = cp.scalar("avg_ptw_latency", "radix")
        nd_all.append(nd_ptw)
        cpu_all.append(cp_ptw)
        rows.append((f"fig4_ptw_{w}", (t1 + t2) * 1e6,
                     f"ndp={nd_ptw:.1f}cyc cpu={cp_ptw:.1f}cyc"))
    inc = (np.mean(nd_all) / np.mean(cpu_all) - 1) * 100
    rows.append(("fig4_ptw_avg", 0.0,
                 f"ndp={np.mean(nd_all):.1f} cpu={np.mean(cpu_all):.1f} "
                 f"increment={inc:.0f}% (paper: 474.56 / +229%)"))
    return rows


def fig5_translation_overhead() -> List[Tuple[str, float, str]]:
    """Fraction of execution spent translating, 4 cores (paper: 67.1% NDP
    vs 34.51% CPU)."""
    rows = []
    nd_all, cpu_all = [], []
    for w in WORKLOADS:
        nd, t1 = _sim(w, "ndp", 4)
        cp, t2 = _sim(w, "cpu", 4)
        ndf = nd.scalar("translation_fraction", "radix")
        cpf = cp.scalar("translation_fraction", "radix")
        nd_all.append(ndf)
        cpu_all.append(cpf)
        rows.append((f"fig5_overhead_{w}", (t1 + t2) * 1e6,
                     f"ndp={ndf:.3f} cpu={cpf:.3f}"))
    rows.append(("fig5_overhead_avg", 0.0,
                 f"ndp={np.mean(nd_all):.3f} cpu={np.mean(cpu_all):.3f} "
                 "(paper: 0.671 / 0.345)"))
    return rows


def fig6_core_scaling() -> List[Tuple[str, float, str]]:
    """PTW latency + overhead vs core count (paper NDP: 242.85 -> 551.83)."""
    rows = []
    for cores in CORE_COUNTS:
        for machine in ("ndp", "cpu"):
            ptws, tfs, us = [], [], 0.0
            for w in WORKLOADS:
                r, t = _sim(w, machine, cores)
                ptws.append(r.scalar("avg_ptw_latency", "radix"))
                tfs.append(r.scalar("translation_fraction", "radix"))
                us += t * 1e6
            rows.append((f"fig6_{machine}_{cores}c", us,
                         f"ptw={np.mean(ptws):.1f} "
                         f"overhead={np.mean(tfs):.3f}"))
    return rows


def fig7_miss_rates() -> List[Tuple[str, float, str]]:
    """L1 miss of PTEs vs data (radix) vs ideal data (paper: 98.28% PTE;
    35.89% vs 26.16% data)."""
    rows = []
    pte, dat, ideal = [], [], []
    for w in WORKLOADS:
        r, t = _sim(w, "ndp", 4)
        pte.append(r.scalar("pte_l1_miss_rate", "radix"))
        dat.append(r.scalar("data_l1_miss_rate", "radix"))
        ideal.append(r.scalar("data_l1_miss_rate", "ideal"))
        rows.append((f"fig7_miss_{w}", t * 1e6,
                     f"pte={pte[-1]:.3f} data={dat[-1]:.3f} "
                     f"ideal={ideal[-1]:.3f}"))
    rows.append(("fig7_miss_avg", 0.0,
                 f"pte={np.mean(pte):.3f} data={np.mean(dat):.3f} "
                 f"ideal={np.mean(ideal):.3f} "
                 "(paper: .983 / .359 / .262)"))
    return rows


def fig8_occupancy() -> List[Tuple[str, float, str]]:
    """Page-table occupancy per level (paper: PL2 98.24%, PL1 97.97%)."""
    rows = []
    occs = []
    for w in WORKLOADS:
        t0 = time.time()
        tr = generate_trace(w, 4, preset=PRESET)
        # occupancy over the dataset's allocated footprint: data-intensive
        # kernels touch essentially all resident pages over the full run;
        # the touched-VPN set of the window under-samples, so evaluate on
        # the footprint range (what the OS has mapped).
        vpns = np.arange(0, tr["pages"], dtype=np.int64)
        l4, l3, l2, l1 = PT.occupancy_by_level(vpns)
        occs.append((l4, l3, l2, l1))
        rows.append((f"fig8_occ_{w}", (time.time() - t0) * 1e6,
                     f"PL4={l4:.4f} PL3={l3:.4f} PL2={l2:.3f} PL1={l1:.3f}"))
    m = np.mean(occs, axis=0)
    rows.append(("fig8_occ_avg", 0.0,
                 f"PL4={m[0]:.4f} PL3={m[1]:.4f} PL2={m[2]:.3f} "
                 f"PL1={m[3]:.3f} (paper: .0043/.0312/.9824/.9797)"))
    return rows


def _speedup_fig(cores: int, fig: str, paper: Dict[str, float]):
    rows = []
    sp = {m: [] for m in DEFAULT_MECHS if m != "radix"}
    for w in WORKLOADS:
        r, t = _sim(w, "ndp", cores)
        s = r.speedup_vs()
        for m in sp:
            sp[m].append(s[m])
        rows.append((f"{fig}_{w}", t * 1e6,
                     " ".join(f"{m}={s[m]:.3f}" for m in sp)))
    avg = {m: float(np.mean(v)) for m, v in sp.items()}
    rows.append((f"{fig}_avg", 0.0,
                 " ".join(f"{m}={avg[m]:.3f}" for m in sp)
                 + f" (paper: {paper})"))
    return rows, avg


def fig12_single_core():
    return _speedup_fig(1, "fig12_1c",
                        {"ech": 1.176, "hugepage": 1.08, "ndpage": 1.344})


def fig13_four_core():
    return _speedup_fig(4, "fig13_4c",
                        {"ech": 1.299, "ndpage": 1.426})


def fig14_eight_core():
    return _speedup_fig(8, "fig14_8c",
                        {"ech": 1.078, "hugepage": 0.901, "ndpage": 1.407})


ALL_FIGS = [fig4_ptw_latency, fig5_translation_overhead, fig6_core_scaling,
            fig7_miss_rates, fig8_occupancy]


def perf_summary() -> Dict:
    """Per-mechanism cycles + engine wall-clock for BENCH_sim.json —
    the perf trajectory future PRs compare against.  ``stages`` breaks
    the fleet wall into trace generation / compile estimate / steady
    run."""
    mech_cycles: Dict[str, List[float]] = {m: [] for m in DEFAULT_MECHS}
    walls = []
    steps = 0
    for (w, machine, cores), (res, wall) in sorted(_CACHE.items()):
        walls.append(wall)
        steps += res.accesses * cores
        if machine == "ndp" and cores == 4:
            for i, m in enumerate(res.mechs):
                mech_cycles[m].append(float(res.cycles.mean(axis=1)[i]))
    total = float(np.sum(walls))
    return {
        "preset": PRESET.name,
        "trace_len": TRACE_LEN,
        "num_sims": len(walls),
        "num_batches": len(_all_buckets()),
        "sim_wall_s_total": round(total, 3),
        "sim_wall_s_mean": round(float(np.mean(walls)), 4) if walls else 0.0,
        "steps_per_sec": round(steps / total, 1) if total else 0.0,
        # compile-free throughput: the regression gate compares this one
        # (a .jax_cache miss must not read as an engine slowdown)
        "steps_per_sec_steady": (round(steps / _STAGES["run_s"], 1)
                                 if _STAGES["run_s"] else 0.0),
        "stages": {k: round(v, 3) for k, v in _STAGES.items()},
        "mechanisms": {
            m: {"mean_cycles_ndp4": round(float(np.mean(v)), 1),
                "speedup_vs_radix": round(
                    float(np.mean(mech_cycles["radix"]) / np.mean(v)), 4)}
            for m, v in mech_cycles.items() if v
        },
    }


def run_all() -> Tuple[List[Tuple[str, float, str]], Dict]:
    rows: List[Tuple[str, float, str]] = []
    summary: Dict = {}
    warm_s = prewarm()
    rows.append(("prewarm_all_sims", warm_s * 1e6,
                 f"{len(_CACHE)} sims in {len(_all_buckets())} batches, "
                 f"{PRESET.name} preset"))
    for fn in ALL_FIGS:
        rows.extend(fn())
    for fn, paper_nd in ((fig12_single_core, 1.344), (fig13_four_core, 1.426),
                         (fig14_eight_core, 1.407)):
        r, avg = fn()
        rows.extend(r)
        summary[fn.__name__] = {"ours": avg, "paper_ndpage": paper_nd}
    summary["perf"] = perf_summary()
    return rows, summary


if __name__ == "__main__":
    import json
    rows, summary = run_all()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps(summary, indent=1))
