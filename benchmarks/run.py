"""Benchmark driver: one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV and writes bench_results.json plus
BENCH_sim.json (per-mechanism cycles + engine wall-clock — the perf
trajectory future PRs compare against).

Sections:
  * Figs 4-8:   address-translation characterization (NDP vs CPU)
  * Figs 12-14: end-to-end speedups of ECH / HugePage / NDPage / Ideal
  * kernels:    serving-layer microbenches (translation, paged attention,
                blockwise attention, engine throughput, simulator speed)

``--fast`` (or SIM_FIGS_FAST=1) runs the simulator figures on the smoke
preset — same engine and orderings, CI wall-clock.  ``--sim-only`` skips
the kernel microbenches.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _setup_host_devices() -> None:
    """Honour SIM_DEVICES=N: expose N XLA host devices so simulate_batch
    can shard its B axis.  Must run before any jax backend initialization
    — that is why it lives here and not inside the library."""
    n = os.environ.get("SIM_DEVICES")
    if not n or int(n) <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={int(n)}"
        ).strip()


def _setup_jax_cache() -> None:
    """Persist XLA binaries so repeat benchmark runs skip compilation."""
    cache = os.environ.get(
        "REPRO_JAX_CACHE_DIR",
        os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
    if not cache:
        return
    import jax
    jax.config.update("jax_compilation_cache_dir", os.path.abspath(cache))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset simulator figures (sub-minute)")
    p.add_argument("--sim-only", action="store_true",
                   help="skip the kernel microbenches")
    p.add_argument("--sweeps", action="store_true",
                   help="also run the sensitivity sweeps "
                        "(benchmarks/sim_sweep.py)")
    args = p.parse_args(argv)
    if args.fast:
        os.environ["SIM_FIGS_FAST"] = "1"

    _setup_host_devices()
    _setup_jax_cache()
    t0 = time.time()
    from benchmarks import sim_figures

    rows = []
    print("name,us_per_call,derived")
    sys.stdout.flush()

    fig_rows, summary = sim_figures.run_all()
    sim_wall = time.time() - t0
    for name, us, derived in fig_rows:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    rows.extend(fig_rows)

    if not args.sim_only:
        from benchmarks import kernel_bench
        for name, us, derived in kernel_bench.run_all():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            rows.append((name, us, derived))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    out = {
        "rows": [{"name": n, "us_per_call": u, "derived": d}
                 for n, u, d in rows],
        "speedup_summary": summary,
    }
    with open(os.path.join(root, "bench_results.json"), "w") as f:
        json.dump(out, f, indent=1)

    bench_sim = dict(summary.get("perf", {}))
    bench_sim["figures_wall_s"] = round(sim_wall, 2)
    bench_sim["speedups"] = {k: v for k, v in summary.items() if k != "perf"}
    with open(os.path.join(root, "BENCH_sim.json"), "w") as f:
        json.dump(bench_sim, f, indent=1)
    print(f"# wrote {os.path.join(root, 'bench_results.json')}")
    print(f"# wrote {os.path.join(root, 'BENCH_sim.json')} "
          f"(figures wall {sim_wall:.1f}s)")

    if args.sweeps:
        # sensitivity sweeps append their section to BENCH_sim.json
        from benchmarks import sim_sweep
        fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))
        srows, ssummary = sim_sweep.run_sweeps(list(sim_sweep._HANDLERS),
                                               fast=fast)
        for name, us, derived in srows:
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        sim_sweep.merge_into_bench_json(
            ssummary, os.path.join(root, "BENCH_sim.json"))
        failed = sim_sweep.failed_checks(ssummary)
        if failed:
            sys.exit(f"sweep ordering checks FAILED: {failed}")


if __name__ == "__main__":
    main()
