"""Benchmark driver: one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV and writes bench_results.json.
Sections:
  * Figs 4-8:   address-translation characterization (NDP vs CPU)
  * Figs 12-14: end-to-end speedups of ECH / HugePage / NDPage / Ideal
  * kernels:    serving-layer microbenches (translation, paged attention,
                blockwise attention, engine throughput, simulator speed)
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    from benchmarks import kernel_bench, sim_figures

    rows = []
    print("name,us_per_call,derived")
    sys.stdout.flush()

    fig_rows, summary = sim_figures.run_all()
    for name, us, derived in fig_rows:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    rows.extend(fig_rows)

    for name, us, derived in kernel_bench.run_all():
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows.append((name, us, derived))

    out = {
        "rows": [{"name": n, "us_per_call": u, "derived": d}
                 for n, u, d in rows],
        "speedup_summary": summary,
    }
    path = os.path.join(os.path.dirname(__file__), "..",
                        "bench_results.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {os.path.abspath(path)}")


if __name__ == "__main__":
    main()
