"""Benchmark driver: one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV and writes bench_results.json plus
BENCH_sim.json (per-mechanism cycles + engine wall-clock — the perf
trajectory future PRs compare against).

Sections (stages):
  * Figs 4-8:   address-translation characterization (NDP vs CPU)
  * Figs 12-14: end-to-end speedups of ECH / HugePage / NDPage / Ideal
  * kernels:    serving-layer microbenches (translation, paged attention,
                blockwise attention, engine throughput, simulator speed)
  * --sweeps:   sensitivity sweeps (benchmarks/sim_sweep.py);
                ``--sweep-presets a,b`` selects a subset
  * --trace-validate: real-vs-synthetic trace comparison
                (benchmarks/trace_validate.py)
  * --serving:  translation-costed serving throughput per mechanism
                (benchmarks/serving_translation.py)
  * --serving-fleet: fleet-scale costed serving — continuous batching
                with prefix sharing and translation-aware admission,
                plus the model-cycles-per-token repricing sweep
                (benchmarks/serving_fleet.py)
  * --search:   seeded design-space search + frontier-regression gate
                (benchmarks/sim_search.py); ``--search-space`` selects
                the space (default: the nightly ``default`` space)
  * --zoo:      related-work mechanism zoo — sim + costed serving +
                zoo-space search + collision analysis, with an
                explicit verdict vs ndpage_search
                (benchmarks/sim_zoo.py)
  * --memory-model: bounded_linear vs banked DRAM comparison — bypass
                margin + flat-vs-radix line-cost gap, with verdict
                (benchmarks/sim_memory.py)

``--fast`` (or SIM_FIGS_FAST=1) runs the simulator figures on the smoke
preset — same engine and orderings, CI wall-clock.  ``--sim-only`` skips
the kernel microbenches.

Every requested stage runs even if an earlier one fails, but ANY stage
failure (an exception, or a failed ordering/validation check) makes the
driver exit non-zero.  The end-of-run summary lists EVERY stage —
passed or failed — with its wall time and exit detail (the exception
message for failures), so a broken stage can never hide in the middle
of a green nightly log and slow stages are visible at a glance.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _setup_host_devices() -> None:
    """Honour SIM_DEVICES=N: expose N XLA host devices so simulate_batch
    can shard its B axis.  Must run before any jax backend initialization
    — that is why it lives here and not inside the library."""
    n = os.environ.get("SIM_DEVICES")
    if not n or int(n) <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={int(n)}"
        ).strip()


def _setup_jax_cache() -> None:
    """Persist XLA binaries so repeat benchmark runs skip compilation."""
    cache = os.environ.get(
        "REPRO_JAX_CACHE_DIR",
        os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
    if not cache:
        return
    import jax
    jax.config.update("jax_compilation_cache_dir", os.path.abspath(cache))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


def _print_rows(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset simulator figures (sub-minute)")
    p.add_argument("--sim-only", action="store_true",
                   help="skip the kernel microbenches")
    p.add_argument("--sweeps", action="store_true",
                   help="also run the sensitivity sweeps "
                        "(benchmarks/sim_sweep.py)")
    p.add_argument("--sweep-presets", default=None,
                   help="comma-separated sweep preset subset (default: "
                        "all) — nightly CI runs a reduced grid")
    p.add_argument("--trace-validate", action="store_true",
                   help="also run the real-vs-synthetic trace "
                        "validation (benchmarks/trace_validate.py)")
    p.add_argument("--serving", action="store_true",
                   help="also run the translation-costed serving "
                        "benchmark (benchmarks/serving_translation.py)")
    p.add_argument("--serving-fleet", action="store_true",
                   help="also run the fleet-scale costed serving "
                        "benchmark — continuous batching, prefix "
                        "sharing, translation-aware admission "
                        "(benchmarks/serving_fleet.py)")
    p.add_argument("--search", action="store_true",
                   help="also run the seeded design-space search and "
                        "frontier-regression gate "
                        "(benchmarks/sim_search.py)")
    p.add_argument("--search-space", default="default",
                   help="SEARCH_SPACES name for --search")
    p.add_argument("--zoo", action="store_true",
                   help="also run the related-work mechanism zoo "
                        "comparison (benchmarks/sim_zoo.py)")
    p.add_argument("--memory-model", action="store_true",
                   help="also run the bounded-vs-banked DRAM memory "
                        "model comparison (benchmarks/sim_memory.py)")
    p.add_argument("--stage-timeout", type=float,
                   default=float(os.environ.get("BENCH_STAGE_TIMEOUT",
                                                "0") or 0),
                   help="wall-clock seconds per stage; a stage still "
                        "running at the deadline is reported TIMEOUT "
                        "(distinct from FAIL), later stages still run, "
                        "and the driver exits non-zero (0 = no limit; "
                        "env BENCH_STAGE_TIMEOUT)")
    args = p.parse_args(argv)
    if args.fast:
        os.environ["SIM_FIGS_FAST"] = "1"
    fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))

    _setup_host_devices()
    _setup_jax_cache()
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    bench_sim_path = os.path.join(root, "BENCH_sim.json")

    # each stage runs isolated: a raising stage is RECORDED (and the
    # driver exits non-zero at the end) but never silently aborts the
    # stages after it — nightly logs show every failure, masked by none.
    # Every stage's outcome, wall time and exit detail land in the
    # end-of-run summary: PASS, FAIL, or TIMEOUT (a stage that was
    # still running at --stage-timeout; the hung thread is abandoned
    # and the remaining stages run on the main thread as usual).
    from repro.util import resilience
    stage_reports: list = []    # (name, status, wall_s, detail)

    def stage(name, fn):
        t0 = time.time()
        try:
            resilience.watchdog_call(fn, args.stage_timeout,
                                     tag=f"stage:{name}", retries=0)
        except resilience.DispatchTimeout as e:
            detail = str(e)
            stage_reports.append((name, "TIMEOUT", time.time() - t0,
                                  detail))
            print(f"# STAGE TIMEOUT: {name} ({detail})", file=sys.stderr)
        except Exception as e:
            traceback.print_exc()
            detail = f"{type(e).__name__}: {e}"
            stage_reports.append((name, "FAIL", time.time() - t0, detail))
            print(f"# STAGE FAILED: {name} ({detail})", file=sys.stderr)
        else:
            stage_reports.append((name, "PASS", time.time() - t0, "ok"))

    rows: list = []
    summary: dict = {}
    print("name,us_per_call,derived")
    sys.stdout.flush()

    def write_bench_results():
        # rewritten after every row-producing stage so a later stage
        # failing never costs the rows already measured
        out = {
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in rows],
            "speedup_summary": summary,
        }
        with open(os.path.join(root, "bench_results.json"), "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {os.path.join(root, 'bench_results.json')}")

    def st_figures():
        t0 = time.time()
        from benchmarks import sim_figures
        fig_rows, fig_summary = sim_figures.run_all()
        sim_wall = time.time() - t0
        _print_rows(fig_rows)
        rows.extend(fig_rows)
        summary.update(fig_summary)
        write_bench_results()

        bench_sim = dict(fig_summary.get("perf", {}))
        bench_sim["figures_wall_s"] = round(sim_wall, 2)
        bench_sim["speedups"] = {k: v for k, v in fig_summary.items()
                                 if k != "perf"}
        with open(bench_sim_path, "w") as f:
            json.dump(bench_sim, f, indent=1)
        print(f"# wrote {bench_sim_path} "
              f"(figures wall {sim_wall:.1f}s)")

    def st_kernels():
        from benchmarks import kernel_bench
        krows = kernel_bench.run_all()
        _print_rows(krows)
        rows.extend(krows)
        write_bench_results()

    def st_sweeps():
        # sensitivity sweeps merge their section into BENCH_sim.json
        from benchmarks import sim_sweep
        presets = (args.sweep_presets.split(",") if args.sweep_presets
                   else list(sim_sweep._HANDLERS))
        srows, ssummary = sim_sweep.run_sweeps(presets, fast=fast)
        _print_rows(srows)
        sim_sweep.merge_into_bench_json(ssummary, bench_sim_path)
        failed = sim_sweep.failed_checks(ssummary)
        if failed:
            raise RuntimeError(f"sweep ordering checks FAILED: {failed}")

    def st_trace_validate():
        from benchmarks import trace_validate
        vrows, vsummary = trace_validate.run_validation(fast=fast)
        _print_rows(vrows)
        trace_validate.merge_into_bench_json(vsummary, bench_sim_path)
        failed = trace_validate.failed_checks(vsummary)
        if failed:
            raise RuntimeError(f"real-trace checks FAILED: {failed}")

    def st_serving():
        from benchmarks import serving_translation
        # always the FULL request mixes: --fast trims the simulator
        # figure preset, but the serving mixes are cheap even at full
        # size and the PR lane already covers the smoke variant
        # (serving_translation.py --smoke --pinned).  source="sweep"
        # makes a broken cost-model derivation FAIL the stage rather
        # than silently serving the pinned fallback.
        srows, ssummary = serving_translation.run_serving(
            fast=False, source="sweep")
        _print_rows(srows)
        serving_translation.merge_into_bench_json(ssummary,
                                                  bench_sim_path)
        failed = serving_translation.failed_checks(ssummary)
        if failed:
            raise RuntimeError(f"serving ordering checks FAILED: {failed}")

    def st_serving_fleet():
        from benchmarks import serving_fleet
        # full fleet mix + the mcpt sweep; source="sweep" so a broken
        # cost-model derivation fails the stage (the PR lane covers the
        # hermetic smoke variant: serving_fleet.py --smoke --pinned)
        frows, fsummary = serving_fleet.run_fleet(fast=False,
                                                  source="sweep")
        _print_rows(frows)
        serving_fleet.merge_into_bench_json(fsummary, bench_sim_path)
        failed = serving_fleet.failed_checks(fsummary)
        if failed:
            raise RuntimeError(f"fleet serving gates FAILED: {failed}")

    def st_search():
        from benchmarks import sim_search
        srows, ssummary = sim_search.run_search(args.search_space)
        _print_rows(srows)
        sim_search.merge_into_bench_json(ssummary, bench_sim_path)
        failed = sim_search.failed_checks(ssummary)
        if failed:
            raise RuntimeError(f"search gates FAILED: {failed}")

    def st_zoo():
        from benchmarks import sim_zoo
        srows, ssummary = sim_zoo.run_all(fast=fast)
        _print_rows(srows)
        sim_zoo.merge_into_bench_json(ssummary, bench_sim_path)
        failed = sim_zoo.failed_checks(ssummary)
        if failed:
            raise RuntimeError(f"zoo checks FAILED: {failed}")

    def st_memory_model():
        from benchmarks import sim_memory
        mrows, msection = sim_memory.run_memory_model(fast=fast)
        _print_rows(mrows)
        rows.extend(mrows)
        write_bench_results()
        sim_memory.merge_into_bench_json(msection, bench_sim_path)
        failed = sim_memory.failed_checks(msection)
        if failed:
            raise RuntimeError(f"memory-model checks FAILED: {failed}")

    stage("figures", st_figures)
    if not args.sim_only:
        stage("kernels", st_kernels)
    if args.sweeps:
        stage("sweeps", st_sweeps)
    if args.trace_validate:
        stage("trace_validate", st_trace_validate)
    if args.serving:
        stage("serving", st_serving)
    if args.serving_fleet:
        stage("serving_fleet", st_serving_fleet)
    if args.search:
        stage("search", st_search)
    if args.zoo:
        stage("zoo", st_zoo)
    if args.memory_model:
        stage("memory_model", st_memory_model)

    # the per-stage summary: every stage with wall time and exit detail
    # — failures quote the exception, timeouts the abandoned deadline,
    # successes say ok.  Recovery events (quarantines, watchdog
    # retries, preemptions) taken along the way are listed so a PASS
    # that leaned on the resilience layer is visible as such.
    print("# stage summary:")
    for name, status, wall, detail in stage_reports:
        print(f"#   {status:<7} {name:<16} {wall:7.1f}s  {detail}")
    events = resilience.recovery_events()
    if events:
        print("# recovery events:")
        for kind, detail in events:
            print(f"#   {kind}: {detail}")
    failures = [(n, s, d) for n, s, _, d in stage_reports if s != "PASS"]
    if failures:
        sys.exit("benchmark stages FAILED: "
                 + "; ".join(f"{n} ({s}: {d})" for n, s, d in failures))


if __name__ == "__main__":
    main()
