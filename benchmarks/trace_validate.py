"""Real-trace validation: pin the synthetic generators to ground truth.

Replays each committed fixture trace (a real-format ChampSim / lackey
file under ``tests/fixtures/traces/``) SIDE BY SIDE with its matched
Table-II synthetic generator — both as lanes of ONE
:func:`repro.sim.simulate_batch` dispatch, since they share the machine
shape — and emits a miss-rate / PTW-latency comparison table:

  * radix L1-DTLB miss rate, PTE L1 hit rate, data L1 miss rate
  * radix average page-table-walk latency (cycles)
  * NDPage end-to-end speedup vs radix

The table lands in ``BENCH_sim.json`` under a ``"real_traces"`` key
(merged into the existing file, never clobbering the figure/sweep
sections), so nightly CI tracks how far the synthetics drift from the
real traces run over run.  Structural checks fail the run: every side
must be translation-intensive (L1-TLB miss rate >= 10% — the property
the paper's whole evaluation rests on) and NDPage must not lose to
radix on a REAL trace (>= 1.0).

Usage:
  python benchmarks/trace_validate.py [--fast] [--cores N]
  python benchmarks/run.py --trace-validate      # same, as a stage
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

FIXTURE_DIR = os.path.join("tests", "fixtures", "traces")

#: (pair name, fixture file, matched synthetic workload)
DEFAULT_PAIRS: Tuple[Tuple[str, str, str], ...] = (
    ("gups", "gups_small.champsim.xz", "rnd"),
    ("graph", "graph_small.lackey.gz", "bc"),
)

Row = Tuple[str, float, str]


def _metrics(res) -> Dict[str, float]:
    return {
        "accesses": int(res.accesses),
        "tlb_miss_rate": round(res.scalar("tlb_miss_rate", "radix"), 4),
        "pte_l1_miss_rate": round(
            res.scalar("pte_l1_miss_rate", "radix"), 4),
        "data_l1_miss_rate": round(
            res.scalar("data_l1_miss_rate", "radix"), 4),
        "radix_ptw_cyc": round(
            res.scalar("avg_ptw_latency", "radix"), 1),
        "ndpage_speedup": round(res.speedup_vs()["ndpage"], 4),
    }


def run_validation(pairs=DEFAULT_PAIRS, fast: bool = True,
                   cores: int = 2) -> Tuple[List[Row], Dict]:
    from repro.configs.ndp_sim import PRESETS, ndp_machine
    from repro.sim import simulate_batch
    from repro.workloads import generate_trace

    preset = PRESETS["smoke" if fast else "full"]
    mach = ndp_machine(cores)
    rows: List[Row] = []
    summary: Dict = {"preset": preset.name, "cores": cores, "pairs": {}}
    for name, fixture, workload in pairs:
        path = (fixture if os.path.isabs(fixture)
                else os.path.join(_ROOT, FIXTURE_DIR, fixture))
        t0 = time.perf_counter()
        synth = generate_trace(workload, cores, preset=preset)
        # real and synthetic share the machine shape: one 2-lane dispatch
        real_res, synth_res = simulate_batch(
            mach, [f"trace:{path}", synth], length=preset.trace_len,
            chunk=preset.chunk)
        wall = time.perf_counter() - t0
        real_m, synth_m = _metrics(real_res), _metrics(synth_res)
        checks = {
            "real_translation_intensive":
                real_m["tlb_miss_rate"] >= 0.10,
            "synthetic_translation_intensive":
                synth_m["tlb_miss_rate"] >= 0.10,
            "ndpage_wins_on_real_trace":
                real_m["ndpage_speedup"] >= 1.0,
        }
        for metric in ("tlb_miss_rate", "pte_l1_miss_rate",
                       "radix_ptw_cyc", "ndpage_speedup"):
            rows.append((
                f"trace_validate_{name}_{metric}", 0.0,
                f"real={real_m[metric]} synth={synth_m[metric]} "
                f"({workload})"))
        ok = all(checks.values())
        rows.append((f"trace_validate_{name}_check", wall * 1e6,
                     f"{'OK' if ok else 'FAIL'} {checks}"))
        summary["pairs"][name] = {
            "fixture": os.path.relpath(path, _ROOT),
            "workload": workload,
            "real": real_m,
            "synthetic": synth_m,
            "checks": checks,
            "wall_s": round(wall, 2),
        }
    return rows, summary


def merge_into_bench_json(summary: Dict, path: str) -> None:
    """Attach the real-trace table to BENCH_sim.json without clobbering
    the figure-suite / sweeps sections already there."""
    data: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# WARNING: could not read existing {path} ({e}); "
                  "rewriting it with the real_traces section only",
                  file=sys.stderr)
    data["real_traces"] = summary
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def failed_checks(summary: Dict) -> List[str]:
    """Pair names whose structural checks failed — shared by this CLI
    and run.py --trace-validate so both exit nonzero."""
    return [n for n, s in summary["pairs"].items()
            if not all(s["checks"].values())]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="smoke-preset windows (CI wall clock)")
    p.add_argument("--cores", type=int, default=2)
    args = p.parse_args(argv)
    fast = args.fast or bool(int(os.environ.get("SIM_FIGS_FAST", "0")))

    from benchmarks.run import _setup_host_devices, _setup_jax_cache
    _setup_host_devices()
    _setup_jax_cache()

    rows, summary = run_validation(fast=fast, cores=args.cores)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    path = os.path.join(_ROOT, "BENCH_sim.json")
    merge_into_bench_json(summary, path)
    print(f"# wrote real_traces section into {path}")

    failed = failed_checks(summary)
    if failed:
        print(f"# REAL-TRACE CHECK FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
